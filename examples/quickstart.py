"""Quickstart — the paper's Listing 1, on TPU/JAX.

Defines a GNNModel in the GNNBuilder API, generates the accelerator
program, runs the fixed-point testbench against the float reference, and
emits the synthesis report.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.gnn import DATASETS
from repro.core.gnn_model import GNNModelConfig, MLPConfig
from repro.core.project import Project
from repro.core.quantization import FPX
from repro.data.pipeline import (compute_average_degree,
                                 compute_average_nodes_and_edges,
                                 graph_dataset)

# -- 1. define the model (paper: gnnb.GNNModel(...)) -----------------------
dataset_cfg = DATASETS["hiv"]
model = GNNModelConfig(
    graph_input_feature_dim=dataset_cfg.node_feat_dim,
    graph_input_edge_dim=dataset_cfg.edge_feat_dim,
    gnn_hidden_dim=16, gnn_num_layers=2, gnn_output_dim=8,
    gnn_conv="sage", gnn_activation="relu", gnn_skip_connection=True,
    global_pooling=("add", "mean", "max"),
    mlp_head=MLPConfig(in_dim=8 * 3, out_dim=1, hidden_dim=8,
                       hidden_layers=3, activation="relu",
                       p_in=8, p_hidden=4, p_out=1),
    gnn_p_in=1, gnn_p_hidden=8, gnn_p_out=4,
)

# -- 2. dataset statistics (paper helpers) ---------------------------------
dataset = graph_dataset(dataset_cfg)
num_nodes_avg, num_edges_avg = compute_average_nodes_and_edges(dataset)
degree_avg = compute_average_degree(dataset)
print(f"dataset: {len(dataset)} graphs, avg nodes {num_nodes_avg}, "
      f"avg edges {num_edges_avg}, avg degree {degree_avg:.2f}")

# -- 3. project: generate, testbench, synthesize ---------------------------
proj = Project(
    "gnn_model", model, "classification_integer", "/tmp/gnnb_quickstart",
    dataset_cfg=dataset_cfg, max_nodes=600, max_edges=600,
    num_nodes_guess=num_nodes_avg, num_edges_guess=num_edges_avg,
    degree_guess=degree_avg, float_or_fixed="fixed", fpx=FPX(16, 10))

proj.gen_hw_model()
proj.init_params()
proj.gen_testbench(num_graphs=32)

tb_data = proj.build_and_run_testbench()
print("tb_data:", tb_data)

synth_data = proj.run_vitis_hls_synthesis()
print("synth_data:", {k: synth_data[k] for k in
                      ("latency_ms", "flops", "hbm_total_bytes",
                       "fits_hbm", "compile_s")})
