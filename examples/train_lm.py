"""End-to-end LM training driver example: a few hundred real optimizer
steps with checkpointing, exact resume, and an injected failure to prove
the fault-tolerant restart path.

  PYTHONPATH=src python examples/train_lm.py --arch qwen3-8b --steps 200
(Reduced same-family config on the host CPU; the full configs are lowered
against the production mesh via `python -m repro.launch.dryrun`.)
"""
import argparse
import shutil

import jax

from repro.configs.registry import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as steps_mod
from repro.launch.train import build_batch_fn
from repro.models import lm
from repro.nn import param as prm
from repro.optim import adamw
from repro.runtime.trainer import (SimulatedFailure, Trainer,
                                   TrainerConfig)

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-8b")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

ckpt_dir = "/tmp/repro_train_example"
shutil.rmtree(ckpt_dir, ignore_errors=True)

cfg = get_config(args.arch, reduced=True)
mesh = make_host_mesh()
bundle = steps_mod.make_train_step(
    cfg, mesh, opt_cfg=adamw.OptConfig(peak_lr=1e-3, warmup_steps=20,
                                       decay_steps=args.steps),
    seq=args.seq, batch=args.batch)
step_fn = bundle.jit()
plan = lm.model_plan(cfg)
params = prm.materialize(plan, jax.random.key(0))
opt_state = prm.materialize(adamw.opt_plan(plan), jax.random.key(1))
print(f"arch={cfg.name} params={prm.count_params(plan):,}")


def new_trainer(fail_at=None):
    return Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=50,
                      ckpt_dir=ckpt_dir, log_every=25),
        step_fn, build_batch_fn(cfg, args.seq, args.batch),
        params, opt_state, fail_at_step=fail_at)


# run with an injected failure at step 120 ...
try:
    new_trainer(fail_at=120).run()
except SimulatedFailure as e:
    print(f"!! {e} — restarting from the latest checkpoint")

# ... and restart: resumes from step 100 and finishes
result = new_trainer().run()
print(f"finished at step {result['final_step']}; "
      f"loss {result['losses'][0]:.4f} -> {result['losses'][-1]:.4f}")
assert result["losses"][-1] < result["losses"][0]
