"""Design space exploration (paper §VII-C / Fig. 5) end to end.

Synthesizes a small design database, fits the direct-fit RF models, then
brute-force explores thousands of candidate designs in milliseconds under
a memory budget — the paper's seconds-vs-days DSE story.

  PYTHONPATH=src python examples/gnn_dse.py [--n 24]
"""
import argparse
import time

from repro.core import dse

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=16, help="designs to synthesize")
args = ap.parse_args()

print(f"design space size: {dse.space_size():,} configurations")

t0 = time.time()
db = dse.build_database(args.n, "/tmp/gnnb_dse_example", seed=0,
                        log=print)
synth_s = time.time() - t0
print(f"'synthesized' (compiled + analysed) {args.n} designs "
      f"in {synth_s:.1f}s ({synth_s / args.n:.2f}s each)")

models = dse.fit_models(db)

t0 = time.time()
best = dse.explore(models, n_candidates=4096, seed=1)
print(f"explored 4096 candidates in {time.time() - t0:.3f}s "
      f"({best['ms_per_eval']:.2f} ms/eval)")
print("best design under the HBM budget"
      + ("" if best["feasible"] else " (NONE FIT — best infeasible)") + ":")
for k in ("conv", "gnn_hidden_dim", "gnn_layers", "gnn_p_hidden",
          "gnn_p_out", "batch_graphs", "node_budget", "edge_budget",
          "pred_latency_s", "pred_hbm_bytes"):
    print(f"  {k}: {best[k]}")
if "pred_graphs_per_s" in best:
    print(f"  pred_graphs_per_s: {best['pred_graphs_per_s']:.0f} "
          f"(packed-batch throughput model)")
