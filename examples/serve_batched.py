"""Batched serving example — both serving modes of repro.launch.serve.

LM mode (default): prefill a batch of prompts, then run the decode loop
with donated KV caches (works for every arch family: attention KV, MLA
compressed cache, mamba / rwkv recurrent state).

  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b

GNN mode (--gnn): drain a graph request queue through fixed-shape packed
GraphBatch programs, optionally sharded across a device mesh
(docs/SERVING.md documents the full request lifecycle).

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
  PYTHONPATH=src python examples/serve_batched.py --gnn --conv gcn \\
      --requests 256 --shards 4
"""
import argparse
import sys

from repro.core import convs as Cv
from repro.launch import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-8b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--gen", type=int, default=48)
ap.add_argument("--gnn", action="store_true",
                help="packed GraphBatch GNN serving instead of LM decode")
ap.add_argument("--conv", default="gcn",
                choices=list(Cv.CONV_TYPES))
ap.add_argument("--requests", type=int, default=256)
ap.add_argument("--batch-graphs", type=int, default=32)
ap.add_argument("--precision", default="fp32",
                choices=["fp32", "bf16", "int8"])
ap.add_argument("--shards", type=int, default=1,
                help="data-parallel device shards (needs >= N devices)")
args = ap.parse_args()

if args.gnn:
    sys.argv = ["serve", "--gnn", "--conv", args.conv,
                "--requests", str(args.requests),
                "--batch-graphs", str(args.batch_graphs),
                "--precision", args.precision,
                "--shards", str(args.shards)]
else:
    sys.argv = ["serve", "--arch", args.arch, "--reduced",
                "--batch", str(args.batch), "--prompt-len", "32",
                "--gen", str(args.gen)]
serve.main()
