"""Batched serving example: prefill a batch of prompts, then run the
decode loop with donated KV caches — the inference-side end-to-end driver
(works for every arch family: attention KV, MLA compressed cache, mamba /
rwkv recurrent state).

  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b
"""
import argparse
import subprocess
import sys

from repro.launch import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-8b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--gen", type=int, default=48)
args = ap.parse_args()

sys.argv = ["serve", "--arch", args.arch, "--reduced",
            "--batch", str(args.batch), "--prompt-len", "32",
            "--gen", str(args.gen)]
serve.main()
