"""Segment-aggregation backend benchmark on packed QM9-like batches.

Times the fused Pallas edge-block kernel (interpret mode everywhere, and
compiled where a TPU backend is available) against the XLA
jax.ops.segment_* path, for every paper aggregation, over the edge stream
of a real packed GraphBatch — the exact layout the convs lower through.
Also sweeps the DSE tile knobs (edge_block/node_block) so measured
timings can seed the perf-model database.

  PYTHONPATH=src python benchmarks/segment_aggregate.py \
      [--batch-graphs 32] [--feat-dim 64] [--repeats 5]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn import DATASETS
from repro.core.aggregations import AGGREGATIONS, segment_aggregate
from repro.data import pipeline as P

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _time(fn, *args, repeats: int = 5) -> float:
    jax.block_until_ready(fn(*args))                  # compile / warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run(batch_graphs: int = 32, feat_dim: int = 64, repeats: int = 5,
        tiles=((64, 32), (128, 64), (256, 128)), log=print) -> dict:
    ds = DATASETS["qm9"]
    node_budget = P.size_budget(batch_graphs, ds.avg_nodes)
    edge_budget = P.size_budget(batch_graphs, ds.avg_nodes * ds.avg_degree)
    graphs = [P.make_graph(ds, i) for i in range(batch_graphs)]
    batch, k = P.pack_graphs(graphs, node_budget, edge_budget, batch_graphs)

    rng = np.random.default_rng(0)
    msgs = jnp.asarray(rng.standard_normal((edge_budget, feat_dim)),
                       jnp.float32)
    dst = jnp.asarray(batch["edge_index"][:, 1])
    valid = jnp.asarray(batch["edge_index"][:, 0] >= 0)
    n = node_budget

    on_tpu = jax.default_backend() == "tpu"
    res = {
        "dataset": "qm9", "batch_graphs": batch_graphs,
        "graphs_packed": int(k), "node_budget": node_budget,
        "edge_budget": edge_budget, "feat_dim": feat_dim,
        "jax_backend": jax.default_backend(), "aggregations": {},
    }
    for agg in AGGREGATIONS:
        xla = jax.jit(lambda m, s, v: segment_aggregate(
            agg, m, s, n, v, backend="xla"))
        xla_s = _time(xla, msgs, dst, valid, repeats=repeats)
        want = np.asarray(xla(msgs, dst, valid))
        entry = {"xla_s": xla_s, "tiles": {}}
        for eb, nb in tiles:
            def pallas_fn(m, s, v, eb=eb, nb=nb, interpret=True):
                return segment_aggregate(agg, m, s, n, v,
                                         backend="pallas", edge_block=eb,
                                         node_block=nb,
                                         interpret=interpret)
            pal = jax.jit(pallas_fn)
            pal_s = _time(pal, msgs, dst, valid, repeats=repeats)
            diff = float(np.max(np.abs(np.asarray(
                pal(msgs, dst, valid)) - want)))
            tile = {"pallas_interpret_s": pal_s, "max_abs_diff": diff,
                    "interpret_speedup_vs_xla": xla_s / pal_s}
            if on_tpu:   # compiled Pallas only where Mosaic is available
                comp = jax.jit(lambda m, s, v: pallas_fn(
                    m, s, v, interpret=False))
                tile["pallas_compiled_s"] = _time(comp, msgs, dst, valid,
                                                  repeats=repeats)
                tile["compiled_speedup_vs_xla"] = \
                    xla_s / tile["pallas_compiled_s"]
            entry["tiles"][f"eb{eb}_nb{nb}"] = tile
            assert diff < 1e-5, (agg, eb, nb, diff)
        res["aggregations"][agg] = entry
        if log:
            best_tile = min(entry["tiles"].items(),
                            key=lambda kv: kv[1]["pallas_interpret_s"])
            log(f"{agg:>4}: xla {xla_s * 1e3:7.3f} ms | pallas "
                f"{best_tile[1]['pallas_interpret_s'] * 1e3:7.3f} ms "
                f"(interpret, best tile {best_tile[0]}, max diff "
                f"{best_tile[1]['max_abs_diff']:.1e})")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "segment_aggregate.json"), "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch-graphs", type=int, default=32)
    ap.add_argument("--feat-dim", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()
    res = run(args.batch_graphs, args.feat_dim, args.repeats)
    print(f"wrote {os.path.join(RESULTS, 'segment_aggregate.json')} "
          f"({res['jax_backend']} backend, equivalence < 1e-5 on all "
          f"aggregations and tiles)")
