"""Giant-graph partitioned inference: padded oracle vs edge-cut sharding.

One oversize request graph — far beyond the packed per-shard budgets —
served two ways:

* **padded oracle**: the single-device program over the dataset's
  worst-case (max_nodes, max_edges) buffers, the path PR 9 retires for
  oversize traffic. It pays for every padding row on every request.
* **partitioned**: ``pipeline.partition_graph`` splits the graph across
  N devices under tight per-device budgets (BFS-front greedy edge cut +
  halo), and ``gnn_model.make_partitioned_apply`` runs the SPMD conv
  stack with per-layer halo exchange plus the single-device reassembly
  tail. Outputs must match the oracle **bitwise** at fp32.

The device count must be fixed before jax initializes, so the parent
spawns one worker subprocess per point with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
benchmarks/sharded_throughput.py mechanism). Each worker probes the
tightest per-device node budget the partitioner fits in — what a
deployment with N devices would size its giant-graph lane at — then
measures both paths and records the modeled comm cost
(``Project.run_synthesis`` ``packed["partitioned"]``, balanced
worst-case cut) next to the measured edge-cut exchange volume
(``GraphPartition.comm_bytes``).

Simulated host devices time-slice one socket, so the measured speedup
comes from retiring the padded program's dead rows (max_nodes vs the
request's actual size), not from N-way parallel conv compute — the
parallel term is what the modeled figures carry (same convention as
benchmarks/sharded_throughput.py). The acceptance gates are bitwise
parity at every device count and >= SPEEDUP_FLOOR measured speedup at
4 devices. JSON lands in benchmarks/results/partitioned_inference.json.

  PYTHONPATH=src python benchmarks/partitioned_inference.py [--smoke]
      [--devices 2 4 8] [--repeats 20]

``--smoke`` sweeps {2, 4} devices and enforces both gates (the CI
benchmark-smoke step).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results")
SPEEDUP_FLOOR = 2.0      # measured padded/partitioned at 4 devices
GATE_DEVICES = 4         # the sweep point the speedup gate reads
MARK = "PARTITIONED_POINT_JSON:"

# heavy-tailed giant-graph traffic: the dataset's declared worst case
# (what the padded oracle must size its buffers for) is ~30x the
# typical oversize request the sweep serves
AVG_NODES = 600
MAX_NODES = 20000
MAX_EDGES = 24000
SEED = 17


def _cfg():
    from repro.core import gnn_model as G
    from repro.data.pipeline import GraphDataConfig
    ds = GraphDataConfig(num_graphs=1, avg_nodes=AVG_NODES, avg_degree=2,
                         node_feat_dim=11, edge_feat_dim=4, num_targets=1,
                         max_nodes=MAX_NODES, max_edges=MAX_EDGES,
                         seed=SEED)
    return ds, G.GNNModelConfig(
        graph_input_feature_dim=ds.node_feat_dim,
        graph_input_edge_dim=ds.edge_feat_dim,
        gnn_hidden_dim=128, gnn_num_layers=3, gnn_output_dim=64,
        gnn_conv="gcn", gnn_skip_connection=True,
        avg_degree=float(ds.avg_degree),
        mlp_head=G.MLPConfig(in_dim=64 * 3, out_dim=1, hidden_dim=64,
                             hidden_layers=2))


def _tight_budget(g, num_parts: int):
    """The smallest per-device node budget (16-row granularity) the
    partitioner fits this graph in at this device count."""
    from repro.data import pipeline as P
    lo = -(-int(g.num_nodes) // num_parts) + 8
    for nb in range(lo, MAX_NODES, 16):
        try:
            return nb, P.partition_graph(g, num_parts, nb, 4 * nb)
        except ValueError:
            continue
    raise RuntimeError(f"graph does not partition into {num_parts} parts")


def worker(num_devices: int, repeats: int) -> dict:
    """Runs inside the subprocess whose XLA_FLAGS pinned the device
    count; measures + models one sweep point and prints it as a single
    marked JSON line for the parent to collect."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import convs as Cv
    from repro.core import gnn_model as G
    from repro.core.project import Project
    from repro.data import pipeline as P
    from repro.launch.mesh import make_data_mesh
    from repro.nn import param as prm

    ds, cfg = _cfg()
    g = P.make_graph(ds, 0)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    nb, part = _tight_budget(g, num_devices)
    mesh = make_data_mesh(num_devices)
    fn = G.make_partitioned_apply(cfg, mesh, None, None,
                                  out_rows=part.padded_nodes)
    stacked = G.stack_shards(part.parts)
    el = {"node_feat": jnp.asarray(g.node_feat),
          "edge_index": jnp.asarray(g.edge_index),
          "edge_feat": jnp.asarray(g.edge_feat),
          "num_nodes": jnp.int32(g.num_nodes)}
    padded_fn = jax.jit(lambda p, e: G.apply(p, cfg, e))

    out_part = np.asarray(fn(params, stacked))           # also warmup
    out_pad = np.asarray(padded_fn(params, el))
    bitwise = bool(np.array_equal(out_part, out_pad))
    max_err = float(np.abs(out_part - out_pad).max())

    def bench(f):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            best = min(best, time.perf_counter() - t0)
        return best

    # two alternating passes per path: a transient load spike during one
    # pass cannot skew the ratio (best-of across both passes)
    t_part = t_pad = float("inf")
    for _ in range(2):
        t_part = min(t_part, bench(lambda: fn(params, stacked)))
        t_pad = min(t_pad, bench(lambda: padded_fn(params, el)))

    # modeled comm cost (balanced worst-case cut through the Project
    # report) vs the measured cut's exchange volume
    proj = Project(f"partitioned_{num_devices}", cfg, "bench",
                   f"/tmp/gnnb_partitioned_bench/{num_devices}",
                   max_nodes=ds.max_nodes, max_edges=ds.max_edges,
                   num_nodes_guess=ds.avg_nodes,
                   num_edges_guess=ds.avg_nodes * ds.avg_degree,
                   degree_guess=ds.avg_degree, batch_graphs=1,
                   node_budget=nb, edge_budget=4 * nb,
                   partition=num_devices)
    proj.gen_hw_model()
    modeled = proj.run_synthesis()["packed"]["partitioned"]
    measured_comm = part.comm_bytes(cfg.gnn_hidden_dim, 4.0,
                                    cfg.gnn_num_layers)

    return {"num_devices": num_devices,
            "devices": len(jax.devices()),
            "graph_nodes": int(g.num_nodes),
            "graph_edges": int(g.num_edges),
            "padded_rows": int(g.node_feat.shape[0]),
            "node_budget": nb,
            "edge_budget": 4 * nb,
            "cut_edges": int(part.cut_edges),
            "halo_nodes": int(part.halo_nodes),
            "bitwise": bitwise,
            "max_err": max_err,
            "partitioned_ms": t_part * 1e3,
            "padded_ms": t_pad * 1e3,
            "speedup": t_pad / max(t_part, 1e-12),
            "measured_comm_bytes": measured_comm,
            "modeled_comm_bytes": modeled["halo_comm_bytes"],
            "modeled_cut_edges": modeled["modeled_cut_edges"],
            "modeled_latency_s": modeled["latency_s"],
            "modeled_padded_latency_s": modeled["padded_oracle_latency_s"]}


def sweep(device_counts, repeats: int, log=print) -> dict:
    """Parent: one subprocess per device count, XLA_FLAGS pinned."""
    points = []
    for n in device_counts:
        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        flags = " ".join(f for f in flags.split()
                         if "xla_force_host_platform_device_count"
                         not in f)
        env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_"
                            f"device_count={n}").strip()
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src") \
            + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, os.path.abspath(__file__), "--worker",
               str(n), "--repeats", str(repeats)]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             timeout=900)
        line = next((ln for ln in out.stdout.splitlines()
                     if ln.startswith(MARK)), None)
        if line is None:
            raise RuntimeError(
                f"worker for {n} devices produced no result:\n"
                f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")
        pt = json.loads(line[len(MARK):])
        points.append(pt)
        if log:
            log(f"devices={n}: partitioned {pt['partitioned_ms']:7.2f} ms "
                f"vs padded {pt['padded_ms']:7.2f} ms "
                f"({pt['speedup']:.2f}x, bitwise={pt['bitwise']}) | "
                f"cut {pt['cut_edges']} edges, exchange "
                f"{pt['measured_comm_bytes'] / 1e3:.0f} kB measured / "
                f"{pt['modeled_comm_bytes'] / 1e3:.0f} kB modeled")
    return {"avg_nodes": AVG_NODES, "max_nodes": MAX_NODES,
            "max_edges": MAX_EDGES, "conv": "gcn", "precision": "fp32",
            "speedup_floor": SPEEDUP_FLOOR, "gate_devices": GATE_DEVICES,
            "points": points}


def check_acceptance(res: dict):
    """Bitwise fp32 parity at every device count; measured speedup over
    the padded oracle >= SPEEDUP_FLOOR at GATE_DEVICES devices."""
    pts = {p["num_devices"]: p for p in res["points"]}
    for n, p in pts.items():
        assert p["bitwise"], (n, p["max_err"])
    gate = pts.get(GATE_DEVICES)
    assert gate is not None, f"sweep has no {GATE_DEVICES}-device point"
    assert gate["speedup"] >= SPEEDUP_FLOOR, \
        (gate["speedup"], SPEEDUP_FLOOR)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: one sweep point
    ap.add_argument("--smoke", action="store_true",
                    help="{2,4}-device sweep + parity/speedup gates "
                         "(the CI step)")
    ap.add_argument("--devices", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--repeats", type=int, default=20)
    args = ap.parse_args()

    if args.worker is not None:
        pt = worker(args.worker, args.repeats)
        print(MARK + json.dumps(pt))
        sys.exit(0)

    counts = [2, 4] if args.smoke else args.devices
    if GATE_DEVICES not in counts:
        counts = sorted(set(counts) | {GATE_DEVICES})
    res = sweep(counts, args.repeats)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "partitioned_inference.json")
    with open(path, "w") as fh:
        json.dump(res, fh, indent=1)
    check_acceptance(res)
    print(f"wrote {path} — acceptance OK (bitwise fp32 parity at every "
          f"device count, >= {SPEEDUP_FLOOR}x measured speedup over the "
          f"padded oracle at {GATE_DEVICES} devices)")
