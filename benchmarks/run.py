"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  perf_model_accuracy   (Fig. 4)  derived = latency/memory CV-MAPE
  dse_speed             (Fig. 5)  derived = orders of magnitude
  accelerator_eval      (Tab. IV / Fig. 6) derived = geomean speedups
  resources             (Fig. 7)  derived = utilization headroom
  roofline              (EXPERIMENTS §Roofline) derived = cells ok

Fast CI defaults; REPRO_BENCH_FULL=1 uses the paper-scale settings
(400-design DB etc. — ~40 min on one CPU core).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def _row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    from benchmarks import accelerator_eval, dse_speed, \
        perf_model_accuracy, resources, roofline_bench

    log = lambda *a: print("#", *a)

    t0 = time.time()
    r = perf_model_accuracy.run(n=400 if FULL else 60, log=log)
    _row("perf_model_accuracy_fig4", (time.time() - t0) * 1e6,
         f"lat_cv_mape={r['latency_cv_mape']:.1f}%|"
         f"mem_cv_mape={r['memory_cv_mape']:.1f}%|paper=36/17.5")

    t0 = time.time()
    r = dse_speed.run(n_synth=20 if FULL else 8, log=log)
    _row("dse_speed_fig5", (time.time() - t0) * 1e6,
         f"synth={r['synthesis_avg_s']:.2f}s|"
         f"model={r['model_avg_ms']:.2f}ms|"
         f"magnitude={r['orders_of_magnitude']:.1f}")

    t0 = time.time()
    r = accelerator_eval.run(n_graphs=200 if FULL else 24,
                             datasets=None if FULL else
                             ["qm9", "esol", "hiv"], log=log)
    g = r["speedups"]["geomean"]
    _row("accelerator_eval_tab4", (time.time() - t0) * 1e6,
         f"vs_jax_cpu={g['vs_jax_cpu']:.2f}x|"
         f"vs_np_cpu={g['vs_np_cpu']:.2f}x|"
         f"vs_base={g['vs_tpu_base']:.2f}x|paper=6.33/7.08")

    t0 = time.time()
    r = resources.run(log=log)
    _row("resources_fig7", (time.time() - t0) * 1e6,
         f"rows={len(r['rows'])}")

    t0 = time.time()
    r = roofline_bench.run(log=log)
    _row("roofline", (time.time() - t0) * 1e6,
         f"cells={r['cells']}|ok={r.get('ok', 0)}")


if __name__ == "__main__":
    main()
