"""Paper Fig. 5: direct-fit model evaluation vs synthesis wall-time.

The paper: 400 Vitis runs ~2 days (9.4 min avg) vs 1.7 ms/model call —
~6 orders of magnitude. Here the synthesis analogue is XLA compile +
report; the model is the fitted RF.
"""
from __future__ import annotations

import json
import math
import os
import time

import numpy as np

from repro.core import dse
from repro.core import perf_model as PM

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run(n_synth: int = 12, n_model_calls: int = 400, log=print) -> dict:
    os.makedirs(RESULTS, exist_ok=True)
    rng = np.random.default_rng(0)
    synth_times = []
    db = []
    for i in range(n_synth):
        d = dse.sample_design(rng)
        t0 = time.time()
        rec = dse.synthesize_design(d, "/tmp/gnnb_dse_speed")
        synth_times.append(time.time() - t0)
        db.append(rec)
    models = dse.fit_models(db)

    designs = [dse.sample_design(rng) for _ in range(n_model_calls)]
    x = np.stack([PM.features(d) for d in designs])
    models.latency.predict(x[:8])            # warm
    t0 = time.time()
    models.latency.predict(x)
    models.memory.predict(x)
    model_s = time.time() - t0

    synth_avg = float(np.mean(synth_times))
    model_avg = model_s / n_model_calls
    res = {
        "synthesis_avg_s": synth_avg,
        "model_avg_ms": model_avg * 1e3,
        "orders_of_magnitude": math.log10(synth_avg / model_avg),
        "paper_synthesis_avg_s": 9.4 * 60,
        "paper_model_avg_ms": 1.7,
        "paper_orders_of_magnitude": math.log10(9.4 * 60 / 1.7e-3),
    }
    with open(os.path.join(RESULTS, "dse_speed.json"), "w") as f:
        json.dump(res, f, indent=1)
    if log:
        log(f"synthesis {synth_avg:.2f}s/design vs model "
            f"{model_avg * 1e3:.2f}ms/design -> "
            f"{res['orders_of_magnitude']:.1f} orders of magnitude "
            f"(paper: {res['paper_orders_of_magnitude']:.1f})")
    return res


if __name__ == "__main__":
    run()
