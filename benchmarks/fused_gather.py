"""Fused gather->phi->aggregate vs the materialized-message path.

Sweeps edge-stream size / feature width / average degree over packed
QM9-like COO layouts and compares the fused Pallas kernel
(`kernels/fused_gather_aggregate`) against the materialized baseline
(gather the (E, F) message tensor with ``jnp.take``, then segment-reduce)
on three axes:

* numerics  — max abs diff (the parity pin, must stay < 1e-5),
* bytes     — modeled HBM traffic of each path (the fused kernel never
              writes/re-reads the (E, F) message tensor),
* throughput — measured edges/s on this host, plus the modeled
              bytes-over-bandwidth edges/s for the paper target
              (TPUTarget.hbm_bw). On CPU CI the Pallas kernel runs in
              interpret mode, so the *modeled* ratio is the acceptance
              proxy; on a TPU the measured ratio is asserted instead.

  PYTHONPATH=src python benchmarks/fused_gather.py [--smoke]
      [--feat-dims 32 64 128] [--degrees 2 4] [--repeats 3]

JSON lands in benchmarks/results/fused_gather.json; --smoke runs the
QM9-like point only and enforces the acceptance gates (parity < 1e-5,
fused modeled bytes < materialized, modeled edge-aggregation throughput
>= 1.2x).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn import DATASETS
from repro.core.aggregations import gather_aggregate
from repro.core.project import TPUTarget
from repro.data import pipeline as P

RESULTS = os.path.join(os.path.dirname(__file__), "results")
F32 = 4          # bytes per element
I32 = 4


def _time(fn, *args, repeats: int = 3) -> float:
    jax.block_until_ready(fn(*args))                  # compile / warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def modeled_bytes(e: int, n: int, f: int, node_block: int) -> dict:
    """HBM traffic model of one edge-aggregation pass.

    materialized: gather reads one (F,) source row per edge, writes the
    (E, F) message tensor, the segment reduce reads it back, and the
    (N, F) output is written once; id streams are read once.

    fused: the (N, F) node table is read once (it stays resident in VMEM
    across the sequential edge axis), the id/scale streams are re-swept
    once per node tile, the output is written once — the (E, F) message
    tensor never exists.
    """
    node_tiles = -(-n // node_block)
    materialized = (e * f * F32          # gather: read source rows
                    + e * f * F32        # write messages
                    + e * f * F32        # reduce: read messages back
                    + n * f * F32        # write aggregates
                    + 2 * e * I32)       # src + dst id streams
    fused = (n * f * F32                 # node table, read once
             + 3 * e * I32 * node_tiles  # src/dst/scale swept per tile
             + n * f * F32)              # write aggregates
    return {"materialized": materialized, "fused": fused,
            "ratio": materialized / fused}


def _edge_stream(n: int, e: int, f: int, seed: int):
    """Synthetic packed-COO edge stream: degree-controlled random ids
    with a padded tail, the layout pack_graphs emits."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    pad = max(e // 8, 1)                 # ~12% padding tail
    src = np.full((e,), -1, np.int32)
    dst = np.full((e,), -1, np.int32)
    src[:e - pad] = rng.integers(0, n, e - pad)
    dst[:e - pad] = rng.integers(0, n, e - pad)
    scale = jnp.asarray(rng.uniform(0.5, 1.5, e), jnp.float32)
    return x, jnp.asarray(src), jnp.asarray(dst), scale


def run_point(n: int, e: int, f: int, *, agg: str = "sum",
              with_scale: bool = True, edge_block: int = 128,
              node_block: int = 128, repeats: int = 3, seed: int = 0,
              on_tpu: bool | None = None) -> dict:
    x, src, dst, scale = _edge_stream(n, e, f, seed)
    if not with_scale:
        scale = None
    valid = src >= 0
    if on_tpu is None:
        on_tpu = jax.default_backend() == "tpu"

    mat = jax.jit(lambda *a: gather_aggregate(
        agg, *a, backend="xla"), static_argnums=(3,))
    fused = jax.jit(lambda *a: gather_aggregate(
        agg, *a, backend="pallas", edge_block=edge_block,
        node_block=node_block, interpret=not on_tpu), static_argnums=(3,))
    args = (x, src, dst, n, valid, scale)
    mat_s = _time(mat, *args, repeats=repeats)
    fused_s = _time(fused, *args, repeats=repeats)
    diff = float(np.max(np.abs(np.asarray(fused(*args))
                               - np.asarray(mat(*args)))))
    bw = TPUTarget().hbm_bw
    bytes_ = modeled_bytes(e, n, f, node_block)
    return {
        "num_nodes": n, "num_edges": e, "feat_dim": f, "agg": agg,
        "with_scale": bool(with_scale), "edge_block": edge_block,
        "node_block": node_block, "max_abs_diff": diff,
        "materialized_s": mat_s, "fused_s": fused_s,
        "measured_edges_per_s": {"materialized": e / mat_s,
                                 "fused": e / fused_s,
                                 "speedup": mat_s / fused_s},
        "modeled_bytes": bytes_,
        "modeled_edges_per_s": {
            "materialized": e / (bytes_["materialized"] / bw),
            "fused": e / (bytes_["fused"] / bw),
            "speedup": bytes_["ratio"]},
        "fused_mode": "compiled" if on_tpu else "interpret",
    }


def run(feat_dims=(32, 64, 128), degrees=(2, 4), batch_graphs: int = 32,
        repeats: int = 3, smoke: bool = False, log=print) -> dict:
    ds = DATASETS["qm9"]
    node_budget = P.size_budget(batch_graphs, ds.avg_nodes)
    res = {"dataset": "qm9", "batch_graphs": batch_graphs,
           "node_budget": node_budget,
           "jax_backend": jax.default_backend(), "points": []}
    if smoke:
        feat_dims, degrees = (64,), (2,)
    for f in feat_dims:
        for deg in degrees:
            edge_budget = P.size_budget(batch_graphs, ds.avg_nodes * deg)
            for agg, sc in (("sum", True), ("mean", False)):
                pt = run_point(node_budget, edge_budget, f, agg=agg,
                               with_scale=sc, repeats=repeats)
                pt["avg_degree"] = deg
                res["points"].append(pt)
                if log:
                    log(f"E={pt['num_edges']:5d} F={f:3d} deg={deg} "
                        f"{agg:>4}: diff {pt['max_abs_diff']:.1e} | "
                        f"modeled bytes {pt['modeled_bytes']['ratio']:.2f}x"
                        f" | measured "
                        f"{pt['measured_edges_per_s']['speedup']:.2f}x "
                        f"({pt['fused_mode']})")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "fused_gather.json"), "w") as fh:
        json.dump(res, fh, indent=1)
    return res


def check_acceptance(res: dict):
    """Parity must hold everywhere; the fused path must beat the
    materialized path on modeled bytes and >= 1.2x modeled (or, on TPU,
    measured) edge-aggregation throughput."""
    on_tpu = res["jax_backend"] == "tpu"
    for pt in res["points"]:
        assert pt["max_abs_diff"] < 1e-5, pt
        assert pt["modeled_bytes"]["fused"] \
            < pt["modeled_bytes"]["materialized"], pt
        speedup = pt["measured_edges_per_s"]["speedup"] if on_tpu \
            else pt["modeled_edges_per_s"]["speedup"]
        assert speedup >= 1.2, (pt, speedup)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single QM9-like point + acceptance gates "
                         "(parity, bytes, >=1.2x modeled throughput)")
    ap.add_argument("--feat-dims", type=int, nargs="+",
                    default=[32, 64, 128])
    ap.add_argument("--degrees", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--batch-graphs", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    res = run(tuple(args.feat_dims), tuple(args.degrees),
              args.batch_graphs, args.repeats, smoke=args.smoke)
    check_acceptance(res)
    print(f"wrote {os.path.join(RESULTS, 'fused_gather.json')} "
          f"({res['jax_backend']} backend) — acceptance OK "
          "(parity < 1e-5, fused wins modeled bytes, >= 1.2x throughput)")
