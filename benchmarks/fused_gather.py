"""Gather->phi->aggregate: v2 DMA kernel vs legacy one-hot vs XLA.

Sweeps edge-stream size / feature width / average degree over packed
QM9-like COO layouts and compares three gather implementations:

* materialized — gather the (E, F) message tensor with ``jnp.take``,
  segment-reduce it (the XLA fallback path),
* onehot       — the legacy fused Pallas kernel: dense (N, EB) one-hot
  MXU contractions, O(N * EB * F) compute per edge block,
* dma          — the v2 fused kernel: scalar-prefetched id streams,
  per-edge dynamic-slice gather, double-buffered scale DMA,
  O(EB * F) per edge block (docs/KERNELS.md §v2).

Each point reports numerics (max abs diff, the parity pin), measured
edges/s on this host, and *modeled* edges/s from the honest roofline
``max(bytes / hbm_bw, flops / peak_flops) + dispatch`` — the compute
term is what the pre-v2 model omitted, letting the one-hot kernel "win"
on modeled bytes while losing ~40x on the clock (the bug this tier
fixes). On CPU CI the Pallas kernels run in interpret mode; interpret
wall-clock still exposes the asymptotic gap (the one-hot kernel does
O(N/NB) more work per edge), so the measured gates hold there too.

  PYTHONPATH=src python benchmarks/fused_gather.py [--smoke] [--compiled]
      [--feat-dims 32 64 128] [--degrees 2 4] [--repeats 3]

JSON lands in benchmarks/results/fused_gather.json; --smoke runs the
QM9-like default point (F=64, deg=2) only and enforces the acceptance
gates:

  1. parity         — every path within 1e-5 of the XLA baseline,
  2. v2 vs legacy   — measured dma >= 5x onehot,
  3. v2 vs XLA      — measured dma not slower than materialized at the
                      default point,
  4. model honesty  — modeled edges/s ranks dma > materialized > onehot,
  5. sign match     — the measured ordering of the three paths agrees
                      with the modeled ordering at the default point.

--compiled additionally runs the dma kernel Mosaic-compiled
(interpret=False). That lowering only exists on a real TPU backend;
elsewhere the step is skipped with a documented log line (CI greps for
it) rather than failing.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn import DATASETS
from repro.core.aggregations import gather_aggregate
from repro.core.convs import gather_compute_flops
from repro.core.project import TPUTarget
from repro.data import pipeline as P

RESULTS = os.path.join(os.path.dirname(__file__), "results")
F32 = 4          # bytes per element
I32 = 4
PATHS = ("materialized", "onehot", "dma")
COMPILED_SKIP_MSG = ("compiled run skipped: Mosaic lowering needs a TPU "
                     "backend; interpret-mode results are the CI proxy")


def _time(fn, *args, repeats: int = 3) -> float:
    jax.block_until_ready(fn(*args))                  # compile / warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def modeled_bytes(e: int, n: int, f: int, node_block: int) -> dict:
    """HBM traffic model of one edge-aggregation pass.

    materialized: gather reads one (F,) source row per edge, writes the
    (E, F) message tensor, the segment reduce reads it back, and the
    (N, F) output is written once; id streams are read once.

    onehot (legacy fused): the (N, F) node table is read once (resident
    across the sequential edge axis), the id/scale streams are re-swept
    once per node tile, the output is written once.

    dma (v2 fused): table once, output once, and the id/scale streams
    exactly once — the grid has no node axis to re-sweep them over.
    """
    node_tiles = -(-n // node_block)
    materialized = (e * f * F32          # gather: read source rows
                    + e * f * F32        # write messages
                    + e * f * F32        # reduce: read messages back
                    + n * f * F32        # write aggregates
                    + 2 * e * I32)       # src + dst id streams
    onehot = (n * f * F32                # node table, read once
              + 3 * e * I32 * node_tiles  # src/dst/scale swept per tile
              + n * f * F32)             # write aggregates
    dma = (n * f * F32                   # node table, read once
           + 3 * e * I32                 # src/dst/scale, single sweep
           + n * f * F32)                # write aggregates
    return {"materialized": materialized, "onehot": onehot, "dma": dma}


def modeled_flops(e: int, n: int, f: int, node_block: int) -> dict:
    """Gather-stage compute per path (convs.gather_compute_flops): the
    materialized path's take/scale/segment-add has the same ~3 E F shape
    as the dma kernel; the one-hot kernel's dense contractions grow with
    N and dominate everything else at realistic node counts."""
    return {"materialized": gather_compute_flops(n, e, f, "dma"),
            "onehot": gather_compute_flops(n, e, f, "onehot", node_block),
            "dma": gather_compute_flops(n, e, f, "dma")}


def modeled_edges_per_s(e: int, n: int, f: int, edge_block: int,
                        node_block: int,
                        target: TPUTarget = TPUTarget()) -> dict:
    """Honest per-path roofline: max(bytes-over-bandwidth,
    FLOPs-over-peak) plus dispatch overhead. The one-hot kernel blocks
    on every (node_tile, edge_tile) grid step; the dma kernel's
    double-buffered scale copies overlap the edge-loop compute, so only
    the single kernel launch pays (DESIGN_BATCHING.md §VMEM residency).
    The materialized path is a short XLA kernel chain — two dispatches
    (gather+scale, segment-reduce)."""
    bytes_ = modeled_bytes(e, n, f, node_block)
    flops = modeled_flops(e, n, f, node_block)
    edge_tiles = -(-e // edge_block)
    node_tiles = -(-n // node_block)
    dispatch = {"materialized": 2, "onehot": edge_tiles * node_tiles,
                "dma": 1}
    out = {}
    for p in PATHS:
        t = max(bytes_[p] / target.hbm_bw, flops[p] / target.peak_flops) \
            + dispatch[p] * target.kernel_step_overhead
        out[p] = e / t
    out["time_s"] = {p: e / out[p] for p in PATHS}
    return out


def _edge_stream(n: int, e: int, f: int, seed: int):
    """Synthetic packed-COO edge stream: degree-controlled random ids
    with a padded tail, the layout pack_graphs emits."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    pad = max(e // 8, 1)                 # ~12% padding tail
    src = np.full((e,), -1, np.int32)
    dst = np.full((e,), -1, np.int32)
    src[:e - pad] = rng.integers(0, n, e - pad)
    dst[:e - pad] = rng.integers(0, n, e - pad)
    scale = jnp.asarray(rng.uniform(0.5, 1.5, e), jnp.float32)
    return x, jnp.asarray(src), jnp.asarray(dst), scale


def run_point(n: int, e: int, f: int, *, agg: str = "sum",
              with_scale: bool = True, edge_block: int = 128,
              node_block: int = 128, repeats: int = 3, seed: int = 0,
              on_tpu: bool | None = None) -> dict:
    x, src, dst, scale = _edge_stream(n, e, f, seed)
    if not with_scale:
        scale = None
    valid = src >= 0
    if on_tpu is None:
        on_tpu = jax.default_backend() == "tpu"

    def make(backend, gather_mode=None):
        return jax.jit(lambda *a: gather_aggregate(
            agg, *a, backend=backend, edge_block=edge_block,
            node_block=node_block, interpret=not on_tpu,
            gather_mode=gather_mode), static_argnums=(3,))

    fns = {"materialized": make("xla"),
           "onehot": make("pallas", "onehot"),
           "dma": make("pallas", "dma")}
    args = (x, src, dst, n, valid, scale)
    times = {p: _time(fns[p], *args, repeats=repeats) for p in PATHS}
    base = np.asarray(fns["materialized"](*args))
    diffs = {p: float(np.max(np.abs(np.asarray(fns[p](*args)) - base)))
             for p in ("onehot", "dma")}
    modeled = modeled_edges_per_s(e, n, f, edge_block, node_block)
    return {
        "num_nodes": n, "num_edges": e, "feat_dim": f, "agg": agg,
        "with_scale": bool(with_scale), "edge_block": edge_block,
        "node_block": node_block, "max_abs_diff": diffs,
        "seconds": times,
        "measured_edges_per_s": {p: e / times[p] for p in PATHS},
        "measured_speedup": {
            "dma_vs_onehot": times["onehot"] / times["dma"],
            "dma_vs_materialized": times["materialized"] / times["dma"]},
        "modeled_bytes": modeled_bytes(e, n, f, node_block),
        "modeled_flops": modeled_flops(e, n, f, node_block),
        "modeled_edges_per_s": {p: modeled[p] for p in PATHS},
        "pallas_mode": "compiled" if on_tpu else "interpret",
    }


def run_compiled_point(n: int, e: int, f: int, *, agg: str = "sum",
                       repeats: int = 3, seed: int = 0, log=print):
    """TPU-only: run the dma kernel Mosaic-compiled (interpret=False)
    and report measured edges/s. Returns None with the documented skip
    line anywhere Mosaic cannot lower (CPU/GPU CI)."""
    if jax.default_backend() != "tpu":
        if log:
            log(COMPILED_SKIP_MSG)
        return None
    x, src, dst, scale = _edge_stream(n, e, f, seed)
    fn = jax.jit(lambda *a: gather_aggregate(
        agg, *a, backend="pallas", interpret=False, gather_mode="dma"),
        static_argnums=(3,))
    args = (x, src, dst, n, src >= 0, scale)
    t = _time(fn, *args, repeats=repeats)
    return {"num_nodes": n, "num_edges": e, "feat_dim": f, "agg": agg,
            "seconds": t, "edges_per_s": e / t, "pallas_mode": "compiled"}


def run(feat_dims=(32, 64, 128), degrees=(2, 4), batch_graphs: int = 32,
        repeats: int = 3, smoke: bool = False, compiled: bool = False,
        log=print) -> dict:
    ds = DATASETS["qm9"]
    node_budget = P.size_budget(batch_graphs, ds.avg_nodes)
    res = {"dataset": "qm9", "batch_graphs": batch_graphs,
           "node_budget": node_budget,
           "jax_backend": jax.default_backend(), "points": []}
    if smoke:
        feat_dims, degrees = (64,), (2,)
    for f in feat_dims:
        for deg in degrees:
            edge_budget = P.size_budget(batch_graphs, ds.avg_nodes * deg)
            for agg, sc in (("sum", True), ("mean", False)):
                pt = run_point(node_budget, edge_budget, f, agg=agg,
                               with_scale=sc, repeats=repeats)
                pt["avg_degree"] = deg
                res["points"].append(pt)
                if log:
                    sp = pt["measured_speedup"]
                    log(f"E={pt['num_edges']:5d} F={f:3d} deg={deg} "
                        f"{agg:>4}: diff {max(pt['max_abs_diff'].values()):.1e}"
                        f" | dma {sp['dma_vs_onehot']:7.1f}x onehot, "
                        f"{sp['dma_vs_materialized']:5.2f}x xla "
                        f"({pt['pallas_mode']})")
    if compiled:
        cpt = run_compiled_point(node_budget,
                                 P.size_budget(batch_graphs,
                                               ds.avg_nodes * 2), 64,
                                 repeats=repeats, log=log)
        res["compiled_point"] = cpt
        if cpt and log:
            log(f"compiled dma: {cpt['edges_per_s']:.3g} edges/s")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "fused_gather.json"), "w") as fh:
        json.dump(res, fh, indent=1)
    return res


def check_acceptance(res: dict):
    """The five --smoke gates (module docstring): parity, dma >= 5x
    onehot measured, dma >= 1x materialized measured at the default
    point, modeled ranking dma > materialized > onehot, and
    modeled-vs-measured ordering agreement at the default point."""
    for pt in res["points"]:
        for p, d in pt["max_abs_diff"].items():
            assert d < 1e-5, (pt["agg"], p, d)
        assert pt["measured_speedup"]["dma_vs_onehot"] >= 5.0, pt
        m = pt["modeled_edges_per_s"]
        assert m["dma"] > m["materialized"] > m["onehot"], m
        if pt["feat_dim"] == 64 and pt["avg_degree"] == 2:
            assert pt["measured_speedup"]["dma_vs_materialized"] >= 1.0, pt
            meas = pt["measured_edges_per_s"]
            rank = sorted(PATHS, key=lambda p: m[p])
            assert rank == sorted(PATHS, key=lambda p: meas[p]), \
                (rank, meas)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="default QM9-like point only + acceptance gates")
    ap.add_argument("--compiled", action="store_true",
                    help="also run the dma kernel Mosaic-compiled "
                         "(TPU only; documented skip elsewhere)")
    ap.add_argument("--feat-dims", type=int, nargs="+",
                    default=[32, 64, 128])
    ap.add_argument("--degrees", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--batch-graphs", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    res = run(tuple(args.feat_dims), tuple(args.degrees),
              args.batch_graphs, args.repeats, smoke=args.smoke,
              compiled=args.compiled)
    check_acceptance(res)
    print(f"wrote {os.path.join(RESULTS, 'fused_gather.json')} "
          f"({res['jax_backend']} backend) — acceptance OK "
          "(parity < 1e-5, dma >= 5x onehot, dma >= 1x materialized at "
          "the default point, modeled ranking dma > materialized > "
          "onehot, measured ordering agrees)")
