"""Chaos serving: fault-injected continuous batching under open load.

The fault-tolerance layer (docs/SERVING.md §Fault tolerance) claims
that under executor crashes, hangs, NaN-corrupted outputs, and
transient slowdowns the scheduler loses nothing and degrades
gracefully. This benchmark proves it on the virtual clock: a seeded
open-loop Poisson trace is served twice through a 4-lane executor pool
(lane 0 runs a real jitted GAT packed program, so the non-finite
output screen is exercised by genuine attention numerics, not just
zero stubs) — once fault-free (the baseline) and once with every lane
wrapped in a
seed-driven ``runtime.faults.FaultyExecutor`` injecting faults at
>= 10% of launches, plus a scripted double-crash on lane 0 so a
quarantine-and-probe-back cycle happens deterministically, plus
malformed graphs in the arrival stream to exercise the admission
guard. Everything is virtual-time and seeded: identical numbers on
every run, zero sleeps, no devices.

Acceptance (``check_acceptance``, the CI ``--smoke`` gate):

* **exactly-once** — every submitted request resolves to exactly one
  terminal status (served / rejected / failed): none lost, none
  duplicated, in both runs;
* **fault dose** — the injected-fault fraction of chaos launches is
  >= FAULT_FRACTION_FLOOR (0.10), so the run actually hurts;
* **availability** — served / admitted under chaos >= the fault-free
  availability minus the injected fault fraction minus
  AVAILABILITY_MARGIN (faults may cost their own capacity, not more);
* **bounded p99 inflation** — chaos p99 <= baseline p99 +
  (max_retries + 1) x (launch timeout + retry backoff cap) +
  P99_SLACK_S (a retried request pays bounded detours, never unbounded
  queueing);
* **probe-back** — the scripted double-crash quarantines lane 0, the
  canary probe succeeds, and the lane serves a regular launch again;
* **admission guard** — every malformed graph is rejected
  ``rejected_invalid``; none reaches a launch.

  PYTHONPATH=src python benchmarks/chaos_serving.py [--smoke]
      [--loads 400 600] [--fault-scales 0.5 1.0 2.0] [--n 800]

JSON lands in benchmarks/results/chaos_serving.json.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from repro.core import gnn_model as G
from repro.data import pipeline as P
from repro.nn import param as prm
from repro.runtime import scheduler as S
from repro.runtime.faults import FaultPlan, FaultSpec, FaultyExecutor

RESULTS = os.path.join(os.path.dirname(__file__), "results")

N_LANES = 4
SERVICE_S = 0.01
BASE_RATES = {"crash": 0.04, "hang": 0.03, "corrupt": 0.04,
              "slowdown": 0.04}
FAULT_FRACTION_FLOOR = 0.10   # the acceptance dose: >=10% of launches
AVAILABILITY_MARGIN = 0.05
P99_SLACK_S = 0.10
INVALID_EVERY = 29            # every 29th arrival is a malformed graph

DS = P.GraphDataConfig(avg_nodes=12, avg_degree=2, node_feat_dim=5,
                       edge_feat_dim=3, max_nodes=96, max_edges=96, seed=11)


def scheduler_config(deadline_s: float = 0.02) -> S.SchedulerConfig:
    node_budget = P.size_budget(4, DS.avg_nodes)
    edge_budget = P.size_budget(4, DS.avg_nodes * DS.avg_degree)
    return S.SchedulerConfig(
        node_budget, edge_budget, max_graphs=4, max_queue_depth=4096,
        default_tier=S.SLOTier("standard", deadline_s, 1),
        launch_timeout_s=0.05, max_retries=2,
        retry_backoff_s=0.01, retry_backoff_cap_s=0.05,
        quarantine_after=2, quarantine_cooldown_s=0.05,
        quarantine_cooldown_cap_s=0.4, validate=True)


def _sim_lane():
    """Cheap real-output lane: zeros per graph row, so the corrupt fault
    has an array to poison and the non-finite screen something to
    check."""
    return S.SimExecutor(
        S.constant_service(SERVICE_S),
        batch_fn=lambda b: np.zeros((len(b["graph_valid"]), 1),
                                    np.float32),
        fallback_fn=lambda g: np.zeros((1,), np.float32))


_GAT_FN = None


def _gat_program():
    """Jitted GAT packed program for lane 0: the fault-free baseline
    pushes real attention outputs (segment-softmax and all) through the
    scheduler's non-finite output screen, proving the guard passes
    finite GAT rows; under chaos the corrupt fault poisons the same
    rows and the screen must catch them."""
    global _GAT_FN
    if _GAT_FN is None:
        cfg = G.GNNModelConfig(
            graph_input_feature_dim=DS.node_feat_dim,
            graph_input_edge_dim=DS.edge_feat_dim,
            gnn_hidden_dim=8, gnn_num_layers=2, gnn_output_dim=8,
            gnn_conv="gat", avg_degree=float(DS.avg_degree),
            mlp_head=G.MLPConfig(in_dim=8 * 3, out_dim=1, hidden_dim=8,
                                 hidden_layers=1))
        params = prm.materialize(G.model_plan(cfg), jax.random.key(7))
        _GAT_FN = jax.jit(lambda b: G.apply_packed(params, cfg, b))
    return _GAT_FN


def _gat_lane():
    """Lane 0: real GAT inference instead of zeros, same service model
    (virtual time stays identical), so every baseline launch on this
    lane exercises the output guard with genuine model numerics."""
    fn = _gat_program()
    return S.SimExecutor(
        S.constant_service(SERVICE_S),
        batch_fn=lambda b: np.asarray(fn(b), np.float32),
        fallback_fn=lambda g: np.zeros((1,), np.float32))


def _lane(i: int):
    return _gat_lane() if i == 0 else _sim_lane()


def _poison(g: P.Graph) -> P.Graph:
    """A malformed request: NaN node features in the active prefix —
    exactly what ``validate_graph`` must reject at admission."""
    nf = np.array(g.node_feat, copy=True)
    nf[: g.num_nodes] = np.nan
    return dataclasses.replace(g, node_feat=nf)


def make_trace(n: int, load: float, seed: int):
    trace = S.poisson_trace(n, load, DS, seed=seed)
    return [(t, _poison(g) if i % INVALID_EVERY == INVALID_EVERY - 1
             else g, tn) for i, (t, g, tn) in enumerate(trace)]


def run_point(n: int, load: float, fault_scale: float, seed: int) -> dict:
    """One (load, fault dose) point: baseline run + chaos run over the
    identical trace and scheduler config. Returns the gated figures."""
    trace = make_trace(n, load, seed)
    cfg = scheduler_config()

    base = S.ContinuousScheduler(cfg, [_lane(i) for i in range(N_LANES)])
    S.run_trace(base, trace)
    bs = base.summary()

    rates = {k: v * fault_scale for k, v in BASE_RATES.items()}
    clock = S.VirtualClock()
    lanes = []
    for i in range(N_LANES):
        plan = FaultPlan.random(seed=seed * N_LANES + i, n_calls=n,
                                rates=rates)
        if i == 0:
            # scripted quarantine trigger: two consecutive crashes on
            # lane 0 (quarantine_after=2), so the probe-back cycle is
            # deterministic at every fault scale
            plan.specs[:0] = [FaultSpec("crash", launch=2),
                              FaultSpec("crash", launch=3)]
            plan._fired[:0] = [False, False]
        lanes.append(FaultyExecutor(_lane(i), plan, clock))
    chaos = S.ContinuousScheduler(cfg, lanes, clock=clock)
    S.run_trace(chaos, trace)
    cs = chaos.summary()

    def accounting(sched, summ):
        ids = sorted(r.req_id for r in sched.responses)
        rejected = (summ["rejected_queue_full"] + summ["rejected_oversize"]
                    + summ["rejected_invalid"])
        admitted = n - rejected
        return {
            "exactly_once": ids == list(range(n)),
            "admitted": admitted,
            "availability": summ["served"] / max(admitted, 1),
        }

    injected = sum(len(l.injected) for l in lanes)
    fault_fraction = injected / max(len(chaos.launches), 1)
    probe_seqs = [e["seq"] for e in chaos.events
                  if e["kind"] == "probe_success" and e["executor"] == 0]
    served_after_probe = bool(probe_seqs) and any(
        l["executor"] == 0 and not l["probe"] and l["status"] == "ok"
        and l["seq"] > probe_seqs[0] for l in chaos.launches)
    n_invalid = sum(1 for i in range(n)
                    if i % INVALID_EVERY == INVALID_EVERY - 1)
    keys = ("served", "failed", "rejected_invalid", "rejected_queue_full",
            "p50_latency_s", "p99_latency_s", "graphs_per_s",
            "retries", "failed_launches", "n_launches")
    return {
        "load_graphs_per_s": load, "n_requests": n,
        "fault_scale": fault_scale,
        "rates": rates,
        "injected_faults": injected,
        "fault_fraction": fault_fraction,
        "n_invalid_submitted": n_invalid,
        "baseline": dict({k: bs.get(k) for k in keys},
                         **accounting(base, bs)),
        "chaos": dict({k: cs.get(k) for k in keys},
                      **accounting(chaos, cs)),
        "probes": cs["probes"],
        "quarantines": sum(1 for e in chaos.events
                           if e["kind"] == "quarantine"),
        "lane0_probed_back_and_served": served_after_probe,
        "p99_bound_s": ((bs["p99_latency_s"] or 0.0)
                        + (cfg.max_retries + 1)
                        * (cfg.launch_timeout_s + cfg.retry_backoff_cap_s)
                        + P99_SLACK_S),
    }


def sweep(loads, fault_scales, n: int, seed: int = 0, log=print) -> dict:
    points = []
    for load in loads:
        for scale in fault_scales:
            pt = run_point(n, float(load), float(scale), seed)
            points.append(pt)
            if log:
                c = pt["chaos"]
                p99 = c["p99_latency_s"]
                log(f"load={load:6.0f} scale={scale:3.1f} | faults "
                    f"{pt['fault_fraction'] * 100:4.1f}% of "
                    f"{c['n_launches']} launches | availability "
                    f"{c['availability'] * 100:5.1f}% "
                    f"(baseline {pt['baseline']['availability'] * 100:5.1f}"
                    f"%) | p99 "
                    f"{'n/a' if p99 is None else f'{p99 * 1e3:6.1f} ms'} "
                    f"(bound {pt['p99_bound_s'] * 1e3:6.1f} ms) | "
                    f"{c['failed']} dead-lettered, {c['retries']} retries, "
                    f"{pt['quarantines']} quarantines, "
                    f"{pt['probes']['succeeded']} probe-backs")
    return {"n_requests": n, "n_lanes": N_LANES, "service_s": SERVICE_S,
            "fault_fraction_floor": FAULT_FRACTION_FLOOR,
            "availability_margin": AVAILABILITY_MARGIN,
            "p99_slack_s": P99_SLACK_S, "points": points}


def check_acceptance(res: dict):
    """The robustness gates — see the module docstring."""
    for pt in res["points"]:
        tag = (pt["load_graphs_per_s"], pt["fault_scale"])
        b, c = pt["baseline"], pt["chaos"]
        assert b["exactly_once"] and c["exactly_once"], \
            (tag, "request lost or duplicated")
        assert c["rejected_invalid"] == b["rejected_invalid"] \
            == pt["n_invalid_submitted"], \
            (tag, "malformed graphs not all rejected at admission")
        if pt["fault_scale"] >= 1.0:
            assert pt["fault_fraction"] >= res["fault_fraction_floor"], \
                (tag, pt["fault_fraction"])
        assert c["availability"] >= b["availability"] \
            - pt["fault_fraction"] - res["availability_margin"], \
            (tag, c["availability"], b["availability"],
             pt["fault_fraction"])
        assert c["served"] > 0 and c["p99_latency_s"] is not None, tag
        assert c["p99_latency_s"] <= pt["p99_bound_s"], \
            (tag, c["p99_latency_s"], pt["p99_bound_s"])
        assert pt["probes"]["succeeded"] >= 1, \
            (tag, "no quarantined lane was ever probed back in")
        assert pt["lane0_probed_back_and_served"], \
            (tag, "lane 0 did not serve a regular launch after probe-back")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single (load, dose) point + all robustness "
                         "gates (the CI step)")
    ap.add_argument("--loads", type=float, nargs="+", default=[400, 600])
    ap.add_argument("--fault-scales", type=float, nargs="+",
                    default=[0.5, 1.0, 2.0])
    ap.add_argument("--n", type=int, default=800)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.smoke:
        res = sweep([600], [1.0], 400, args.seed)
    else:
        res = sweep(args.loads, args.fault_scales, args.n, args.seed)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "chaos_serving.json")
    with open(path, "w") as fh:
        json.dump(res, fh, indent=1)
    check_acceptance(res)
    print(f"wrote {path} — robustness gates OK (exactly-once, "
          f"availability within {AVAILABILITY_MARGIN:.0%} + fault dose "
          f"of baseline, p99 within the retry bound, quarantine "
          f"probe-back observed, invalid inputs rejected at admission)")
