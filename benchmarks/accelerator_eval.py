"""Paper Table IV + Fig. 6: generated-accelerator speedup evaluation.

Implementations compared (batch=1 per-graph latency, as in the paper):
  jax-cpu  — PyG-CPU analogue: jitted XLA float32 segment-op model,
             measured on this host CPU.
  np-cpu   — C++-CPU analogue: pure-NumPy forward (no XLA), measured.
  tpu-base — FPGA-Base analogue: generated program, parallelism 1,
             <32,16> fixed point; latency = modeled roofline of the
             compiled artifact (the paper likewise reports the
             post-synthesis worst-case estimate, not silicon).
  tpu-par  — FPGA-Parallel analogue: p_hidden=16/p_out=8 (PNA 8/8),
             <16,10>; modeled likewise.

Grid: conv in {gcn, gin, pna, sage} x five MoleculeNet-statistics
datasets. Reported: per-conv speedups of tpu-par over each baseline +
geometric means (paper: 6.33x PyG-CPU, 6.87x PyG-GPU, 7.08x C++-CPU).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn import DATASETS, FPX_BASE, FPX_PARALLEL, \
    benchmark_config
from repro.core import gnn_model as G
from repro.core.project import Project
from repro.data.pipeline import make_graph
from repro.nn import param as prm

RESULTS = os.path.join(os.path.dirname(__file__), "results")
CONVS = ("gcn", "gin", "pna", "sage")


# ------------------------------------------------- numpy (cpp) baseline --
def _np_linear(p, x):
    y = x @ np.asarray(p["w"], np.float32)
    if "b" in p:
        y = y + np.asarray(p["b"], np.float32)
    return y


def numpy_forward(params, cfg, g) -> np.ndarray:
    """Pure-NumPy reference forward (the C++ float CPU analogue)."""
    relu = lambda a: np.maximum(a, 0.0)
    x = g.node_feat.copy()
    n = g.num_nodes
    ei = g.edge_index[:g.num_edges]
    src, dst = ei[:, 0], ei[:, 1]
    indeg = np.bincount(dst, minlength=cfg_max_nodes(cfg, g)) \
        .astype(np.float32)
    for i in range(cfg.gnn_num_layers):
        cc = cfg.conv_cfg(i)
        pc = params["convs"][f"c{i}"]
        if cfg.gnn_conv == "gcn":
            inv = 1.0 / np.sqrt(np.maximum(indeg + 1.0, 1e-12))
            msg = (x * inv[:, None])[src]
            agg = np.zeros_like(x)
            np.add.at(agg, dst, msg)
            agg = (agg + x * inv[:, None]) * inv[:, None]
            h = _np_linear(pc["w"], agg)
        elif cfg.gnn_conv == "sage":
            agg = np.zeros_like(x)
            cnt = np.zeros((x.shape[0], 1), np.float32)
            np.add.at(agg, dst, x[src])
            np.add.at(cnt, dst, 1.0)
            agg = agg / np.maximum(cnt, 1.0)
            h = _np_linear(pc["w_self"], x) + _np_linear(pc["w_neigh"], agg)
        elif cfg.gnn_conv == "gin":
            msg = x[src]
            if "w_edge" in pc:
                msg = relu(msg + _np_linear(pc["w_edge"],
                                            g.edge_feat[:g.num_edges]))
            agg = np.zeros_like(x)
            np.add.at(agg, dst, msg)
            eps = float(np.asarray(pc["eps"]))
            h = _np_linear(pc["mlp2"],
                           relu(_np_linear(pc["mlp1"], (1 + eps) * x + agg)))
        else:  # pna
            feats = [x[dst], x[src], g.edge_feat[:g.num_edges].repeat(1, 0)
                     if False else g.edge_feat[:g.num_edges]]
            msg = relu(_np_linear(pc["pre"], np.concatenate(
                [x[dst], x[src], g.edge_feat[:g.num_edges]], axis=-1)))
            s = np.zeros_like(x[:, :msg.shape[1]])
            c = np.zeros((x.shape[0], 1), np.float32)
            mn = np.full_like(s, np.inf)
            mx = np.full_like(s, -np.inf)
            s2 = np.zeros_like(s)
            np.add.at(s, dst, msg)
            np.add.at(s2, dst, msg ** 2)
            np.add.at(c, dst, 1.0)
            np.minimum.at(mn, dst, msg)
            np.maximum.at(mx, dst, msg)
            cc_ = np.maximum(c, 1.0)
            mean = s / cc_
            # stable two-pass-equivalent std
            var = np.maximum(s2 / cc_ - mean ** 2, 1e-12)
            std = np.sqrt(var)
            mn = np.where(np.isfinite(mn), mn, 0.0)
            mx = np.where(np.isfinite(mx), mx, 0.0)
            logd = np.log(np.maximum(indeg, 1.0) + 1.0)[:, None]
            towers = []
            for t in (mean, mn, mx, std):
                towers += [t, t * (logd / cfg.pna_delta),
                           t * (cfg.pna_delta / logd)]
            h = _np_linear(pc["post"],
                           np.concatenate([x] + towers, axis=-1))
        if cfg.gnn_skip_connection:
            skip = x
            if f"skip{i}" in params:
                skip = _np_linear(params[f"skip{i}"], x)
            h = h + skip
        x = relu(h)
        mask = (np.arange(x.shape[0]) < n)[:, None]
        x = x * mask
    pooled = np.concatenate([
        x[:n].sum(0), x[:n].mean(0), x[:n].max(0)])
    h = pooled
    mcfg = cfg.mlp_head
    for i in range(mcfg.hidden_layers + 1):
        h = _np_linear(params["mlp"][f"l{i}"], h)
        if i < mcfg.hidden_layers:
            h = relu(h)
    return h


def cfg_max_nodes(cfg, g):
    return g.node_feat.shape[0]


# ----------------------------------------------------------- evaluation --
def run(n_graphs: int = 32, datasets=None, log=print) -> dict:
    os.makedirs(RESULTS, exist_ok=True)
    datasets = datasets or list(DATASETS)
    rows = []
    for conv in CONVS:
        for ds_name in datasets:
            ds_cfg = DATASETS[ds_name]
            cfg_par = benchmark_config(conv, ds_name, parallel=True)
            cfg_base = benchmark_config(conv, ds_name, parallel=False)
            plan = G.model_plan(cfg_par)
            params = prm.materialize(plan, jax.random.key(0))
            np_params = jax.tree_util.tree_map(
                lambda a: np.asarray(a, np.float32), params)
            graphs = [make_graph(ds_cfg, i) for i in range(n_graphs)]

            # jax-cpu measured (PyG-CPU analogue)
            fn = jax.jit(lambda p, el: G.apply(p, cfg_par, el, None))
            els = [{"node_feat": jnp.asarray(g.node_feat),
                    "edge_index": jnp.asarray(g.edge_index),
                    "edge_feat": jnp.asarray(g.edge_feat),
                    "num_nodes": jnp.int32(g.num_nodes)} for g in graphs]
            jax.block_until_ready(fn(params, els[0]))
            t0 = time.perf_counter()
            for el in els:
                out = fn(params, el)
            jax.block_until_ready(out)
            jax_ms = (time.perf_counter() - t0) / n_graphs * 1e3

            # numpy measured (C++-CPU analogue)
            t0 = time.perf_counter()
            for g in graphs:
                numpy_forward(np_params, cfg_par, g)
            np_ms = (time.perf_counter() - t0) / n_graphs * 1e3

            # generated accelerators: modeled roofline latency
            lat = {}
            for tag, mcfg, fpx in (("tpu-base", cfg_base, FPX_BASE),
                                   ("tpu-par", cfg_par, FPX_PARALLEL)):
                proj = Project(f"bench_{conv}_{ds_name}_{tag}", mcfg,
                               "bench", f"/tmp/gnnb_bench/{tag}",
                               dataset_cfg=ds_cfg, float_or_fixed="fixed",
                               fpx=fpx)
                proj.gen_hw_model()
                rep = proj.run_synthesis()
                lat[tag] = rep["latency_ms"]

            rows.append({
                "conv": conv, "dataset": ds_name,
                "jax_cpu_ms": jax_ms, "np_cpu_ms": np_ms,
                "tpu_base_ms": lat["tpu-base"],
                "tpu_par_ms": lat["tpu-par"],
            })
            if log:
                log(f"  {conv}/{ds_name}: jax {jax_ms:.2f}ms "
                    f"np {np_ms:.2f}ms base {lat['tpu-base']:.4f}ms "
                    f"par {lat['tpu-par']:.4f}ms")

    # per-conv + overall geomean speedups of tpu-par
    def geomean(v):
        return float(np.exp(np.mean(np.log(np.maximum(v, 1e-12)))))

    summary = {}
    for conv in CONVS:
        sub = [r for r in rows if r["conv"] == conv]
        summary[conv] = {
            "vs_jax_cpu": geomean(np.array(
                [r["jax_cpu_ms"] / r["tpu_par_ms"] for r in sub])),
            "vs_np_cpu": geomean(np.array(
                [r["np_cpu_ms"] / r["tpu_par_ms"] for r in sub])),
            "vs_tpu_base": geomean(np.array(
                [r["tpu_base_ms"] / r["tpu_par_ms"] for r in sub])),
        }
    summary["geomean"] = {
        k: geomean(np.array([summary[c][k] for c in CONVS]))
        for k in ("vs_jax_cpu", "vs_np_cpu", "vs_tpu_base")}
    res = {"rows": rows, "speedups": summary,
           "paper": {"vs_pyg_cpu": 6.33, "vs_pyg_gpu": 6.87,
                     "vs_cpp_cpu": 7.08}}
    with open(os.path.join(RESULTS, "accelerator_eval.json"), "w") as f:
        json.dump(res, f, indent=1)
    if log:
        log(f"geomean speedups (tpu-par): {summary['geomean']}")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--datasets", nargs="*", default=None)
    args = ap.parse_args()
    run(args.n, args.datasets)
