"""Packed GraphBatch throughput vs the per-graph padded loop.

Acceptance benchmark for the GraphBatch IR (DESIGN_BATCHING.md): a packed
batch of >= 32 QM9-like graphs runs through ``apply_packed`` as one jitted
program and must (a) match the per-graph ``apply`` outputs within 1e-4 MAE
and (b) deliver >= 5x graphs/s over the padded per-graph loop at equal
model config. The padded loop pads every graph to max_nodes (600 for the
QM9 stand-in) — the ~97% node-slot waste this refactor removes.

Sweeps all four paper convs by default and, per conv, also times the
fused gather->aggregate path (``aggregations.backend_scope("pallas")``,
which lowers the linear convs through ``kernels/fused_gather_aggregate``)
next to the unfused XLA path — the per-conv fused/unfused graphs/s pairs
seed the perf trajectory in the results JSON. On non-TPU hosts the fused
program runs the kernels in interpret mode; the number is recorded
either way (flagged ``fused_mode``).

  PYTHONPATH=src python benchmarks/packed_throughput.py [--n 64] \
      [--batch-graphs 32] [--convs gcn sage gin pna] [--no-fused]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn import DATASETS, benchmark_config
from repro.core import convs as Cv
from repro.core import aggregations as agg_mod
from repro.core import gnn_model as G
from repro.data import pipeline as P
from repro.nn import param as prm

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run(conv: str = "gcn", dataset: str = "qm9", n_graphs: int = 64,
        batch_graphs: int = 32, repeats: int = 3, fused: bool = False,
        log=print) -> dict:
    cfg = benchmark_config(conv, dataset, parallel=True)
    ds = DATASETS[dataset]
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    graphs = [P.make_graph(ds, i) for i in range(n_graphs)]

    # --- per-graph padded loop (the seed's execution model) -------------
    loop_fn = jax.jit(lambda p, el: G.apply(p, cfg, el))
    els = [{"node_feat": jnp.asarray(g.node_feat),
            "edge_index": jnp.asarray(g.edge_index),
            "edge_feat": jnp.asarray(g.edge_feat),
            "num_nodes": jnp.int32(g.num_nodes)} for g in graphs]
    jax.block_until_ready(loop_fn(params, els[0]))        # compile
    loop_s = []
    refs = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        outs = [loop_fn(params, el) for el in els]
        jax.block_until_ready(outs)
        loop_s.append(time.perf_counter() - t0)
        refs = [np.asarray(o) for o in outs]
    loop_gps = n_graphs / min(loop_s)

    # --- packed GraphBatch path ----------------------------------------
    node_budget = P.size_budget(batch_graphs, ds.avg_nodes)
    edge_budget = P.size_budget(batch_graphs,
                                ds.avg_nodes * ds.avg_degree)
    batches, dropped = P.pack_dataset(graphs, node_budget, edge_budget,
                                      batch_graphs)
    packed_fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))
    dev = [G.packed_to_device(b) for b in batches]
    for b in dev:                                         # compile
        jax.block_until_ready(packed_fn(params, b))
    packed_s = []
    packed_outs = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        outs = [packed_fn(params, b) for b in dev]
        jax.block_until_ready(outs)
        packed_s.append(time.perf_counter() - t0)
        packed_outs = [np.asarray(o) for o in outs]
    n_packed = sum(int(b["num_graphs"]) for b in batches)
    packed_gps = n_packed / min(packed_s)

    # --- fused gather->aggregate path (Pallas backend) ------------------
    fused_gps = fused_mode = None
    if fused:
        on_tpu = jax.default_backend() == "tpu"
        fused_mode = "compiled" if on_tpu else "interpret"
        with agg_mod.backend_scope("pallas"):
            fused_fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))
            jax.block_until_ready(fused_fn(params, dev[0]))  # compile
            fused_t = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                outs = [fused_fn(params, b) for b in dev]
                jax.block_until_ready(outs)
                fused_t.append(time.perf_counter() - t0)
        fused_gps = n_packed / min(fused_t)

    # --- equivalence ----------------------------------------------------
    ref_iter = iter(r for g, r in zip(graphs, refs)
                    if P.graph_fits_budget(g, node_budget, edge_budget))
    maes = []
    for b, out in zip(batches, packed_outs):
        for i in range(int(b["num_graphs"])):
            maes.append(float(np.mean(np.abs(out[i] - next(ref_iter)))))
    mae = float(np.mean(maes))

    res = {
        "conv": conv, "dataset": dataset, "n_graphs": n_graphs,
        "batch_graphs": batch_graphs,
        "node_budget": node_budget, "edge_budget": edge_budget,
        "n_batches": len(batches), "n_dropped": len(dropped),
        "loop_graphs_per_s": loop_gps,
        "packed_graphs_per_s": packed_gps,
        "unfused_graphs_per_s": packed_gps,
        "fused_graphs_per_s": fused_gps,
        "fused_mode": fused_mode,
        "speedup": packed_gps / loop_gps,
        "mae_vs_loop": mae,
        "padded_node_slots": n_graphs * ds.max_nodes,
        "packed_node_slots": len(batches) * node_budget,
    }
    if log:
        fused_txt = "" if fused_gps is None else \
            f", fused {fused_gps:.0f} graphs/s ({fused_mode})"
        log(f"{conv}/{dataset}: loop {loop_gps:.0f} graphs/s, packed "
            f"{packed_gps:.0f} graphs/s ({res['speedup']:.1f}x)"
            f"{fused_txt}, MAE {mae:.2e}, slots "
            f"{res['packed_node_slots']} vs "
            f"{res['padded_node_slots']} padded")
    return res


def run_all(convs=None, dataset: str = "qm9",
            n_graphs: int = 64, batch_graphs: int = 32, repeats: int = 3,
            fused: bool = True, log=print) -> dict:
    """Sweep every conv and record per-conv fused/unfused graphs/s —
    the perf-trajectory seed for the fused edge pipeline."""
    if convs is None:
        convs = Cv.CONV_TYPES          # registry-derived: gat included
    res = {"dataset": dataset, "n_graphs": n_graphs,
           "batch_graphs": batch_graphs,
           "jax_backend": jax.default_backend(), "convs": {}}
    for conv in convs:
        res["convs"][conv] = run(conv, dataset, n_graphs, batch_graphs,
                                 repeats, fused=fused, log=log)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "packed_throughput.json"), "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--convs", nargs="+",
                    default=list(Cv.CONV_TYPES),
                    choices=list(Cv.CONV_TYPES))
    ap.add_argument("--dataset", default="qm9")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--batch-graphs", type=int, default=32)
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the Pallas fused-path timing (slow off-TPU)")
    args = ap.parse_args()
    res = run_all(tuple(args.convs), args.dataset, args.n,
                  args.batch_graphs, fused=not args.no_fused)
    for conv, r in res["convs"].items():
        assert r["mae_vs_loop"] < 1e-4, (conv, r["mae_vs_loop"])
        assert r["speedup"] >= 5.0, (conv, r["speedup"])
    print("acceptance: OK (>=5x, MAE < 1e-4, all convs)")
