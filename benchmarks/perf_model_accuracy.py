"""Paper Fig. 4: direct-fit performance-model accuracy.

Builds a database of synthesized designs (XLA compile + report = the
Vitis-HLS synthesis analogue), fits the RF latency and memory models, and
reports 5-fold CV MAPE — the paper's numbers are ~36 % (latency) and
~17-18 % (BRAM). Latency target = modeled roofline latency of the compiled
artifact; with --measured the target is the *measured* testbench runtime
(noisier — closer to the paper's HLS-report target).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import dse
from repro.core import perf_model as PM

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run(n: int = 400, seed: int = 0, measured: bool = False,
        log=print) -> dict:
    os.makedirs(RESULTS, exist_ok=True)
    db_path = os.path.join(RESULTS, f"design_db_{n}_{int(measured)}.json")
    if os.path.exists(db_path):
        with open(db_path) as f:
            db = json.load(f)
        if log:
            log(f"loaded cached design DB ({len(db)} designs)")
    else:
        t0 = time.time()
        db = dse.build_database(n, "/tmp/gnnb_dse", seed=seed,
                                run_testbench=measured, log=log)
        if log:
            log(f"synthesized {n} designs in {time.time() - t0:.0f}s")
        with open(db_path, "w") as f:
            json.dump(db, f)

    x = np.stack([PM.features(d) for d in db])
    lat_key = "measured_ms" if measured else "latency_s"
    y_lat = np.array([d[lat_key] for d in db])
    y_mem = np.array([d["hbm_bytes"] for d in db])

    res = {
        "n_designs": len(db),
        "latency_cv_mape": PM.kfold_cv_mape(x, y_lat, k=5),
        "memory_cv_mape": PM.kfold_cv_mape(x, y_mem, k=5),
        "latency_target": lat_key,
        "paper_latency_mape": 36.0,
        "paper_bram_mape": 17.5,
    }
    with open(os.path.join(RESULTS, "perf_model_accuracy.json"), "w") as f:
        json.dump(res, f, indent=1)
    if log:
        log(f"latency CV-MAPE {res['latency_cv_mape']:.1f}% "
            f"(paper ~36%), memory CV-MAPE {res['memory_cv_mape']:.1f}% "
            f"(paper ~17.5%)")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--measured", action="store_true")
    args = ap.parse_args()
    run(args.n, measured=args.measured)
