"""Precision-polymorphic packed inference: fp32 vs bf16 vs int8-fixed.

For each precision the same model runs the packed GraphBatch program
under its PrecisionPolicy (low-precision node/message tiles, fp32
accumulation) and is compared on three axes:

* numerics — output error vs the fp32 program (the parity pin: bf16
  must keep SQNR above 30 dB with a 1e-1 absolute ceiling at this model
  size, int8 must keep SQNR above 10 dB after max-abs calibration),
* bytes — the modeled program bytes from ``Project.run_synthesis``
  (cost_analysis scaled by the policy byte width — what the DSE
  forests price), plus the modeled graphs/s they imply,
* throughput — measured packed graphs/s on this host. On CPU the
  low-precision paths run fake-quant emulation, so the *modeled* ratio
  is the acceptance proxy (same convention as benchmarks/fused_gather);
  on a TPU the measured ratio is what matters.

  PYTHONPATH=src python benchmarks/precision_throughput.py [--smoke]
      [--convs gcn sage] [--n 64] [--batch-graphs 32]

JSON lands in benchmarks/results/precision_throughput.json; --smoke
runs the gcn point only and enforces the acceptance gates (parity at
every precision, bf16 and int8 beating fp32 on modeled bytes by the
1.5x floor).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.gnn import DATASETS
from repro.core import convs as Cv
from repro.core import gnn_model as G
from repro.core import quantization as Q
from repro.data import pipeline as P
from repro.nn import param as prm

RESULTS = os.path.join(os.path.dirname(__file__), "results")
PRECISIONS = ("fp32", "bf16", "int8")
BYTES_FLOOR = 1.5        # low precision must cut modeled bytes >= 1.5x
# model-level parity gates for this (hidden 64, 3-linear head) config:
# bf16 rounding accumulates past the reduced-config 5e-2 budget, so the
# robust gate is SQNR with a loose absolute ceiling
BF16_TOL = 1e-1          # bf16 absolute ceiling at this model size
BF16_SQNR_FLOOR = 30.0   # dB, bf16 output vs fp32
INT8_SQNR_FLOOR = 10.0   # dB, calibrated int8 output vs fp32


def _cfg(conv: str, ds) -> G.GNNModelConfig:
    return G.GNNModelConfig(
        graph_input_feature_dim=ds.node_feat_dim,
        graph_input_edge_dim=ds.edge_feat_dim,
        gnn_hidden_dim=64, gnn_num_layers=2, gnn_output_dim=32,
        gnn_conv=conv, gnn_skip_connection=True,
        avg_degree=float(ds.avg_degree),
        mlp_head=G.MLPConfig(in_dim=32 * 3, out_dim=1, hidden_dim=32,
                             hidden_layers=2))


def _modeled(conv: str, precision: str, batch_graphs: int,
             build_root: str) -> dict:
    """Project synthesis for this (conv, precision): the width-scaled
    modeled bytes + roofline graphs/s the DSE objective sees."""
    from repro.core.project import Project
    ds = DATASETS["qm9"]
    proj = Project(f"prec_{conv}_{precision}", _cfg(conv, ds), "bench",
                   os.path.join(build_root, f"{conv}_{precision}"),
                   max_nodes=ds.max_nodes, max_edges=ds.max_edges,
                   num_nodes_guess=ds.avg_nodes,
                   num_edges_guess=ds.avg_nodes * ds.avg_degree,
                   degree_guess=ds.avg_degree,
                   batch_graphs=batch_graphs, precision=precision)
    proj.gen_hw_model()
    rep = proj.run_synthesis()["packed"]
    return {"bytes": rep["bytes_accessed"],
            "graphs_per_s": rep["graphs_per_s"],
            "compute_bytes": rep["compute_bytes"]}


def run_point(conv: str, n_graphs: int, batch_graphs: int,
              repeats: int, build_root: str, log=print) -> dict:
    ds = DATASETS["qm9"]
    cfg = _cfg(conv, ds)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    graphs = [P.make_graph(ds, i) for i in range(n_graphs)]
    node_budget = P.size_budget(batch_graphs, ds.avg_nodes)
    edge_budget = P.size_budget(batch_graphs,
                                ds.avg_nodes * ds.avg_degree)
    batches, _ = P.pack_dataset(graphs, node_budget, edge_budget,
                                batch_graphs)
    dev = [G.packed_to_device(b) for b in batches]
    n_packed = sum(int(b["num_graphs"]) for b in batches)

    out = {"conv": conv, "n_graphs": n_packed,
           "batch_graphs": batch_graphs, "precisions": {}}
    ref_outs = None
    for precision in PRECISIONS:
        policy = G.calibrated_policy(params, cfg, dev[0], precision)
        fn = jax.jit(lambda p, b, pol=policy: G.apply_packed(
            p, cfg, b, None, pol))
        for b in dev:                                    # compile
            jax.block_until_ready(fn(params, b))
        best = float("inf")
        outs = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            outs = [fn(params, b) for b in dev]
            jax.block_until_ready(outs)
            best = min(best, time.perf_counter() - t0)
        outs = [np.asarray(o) for o in outs]
        if precision == "fp32":
            ref_outs = outs
        err = Q.error_stats(
            np.concatenate([o[:int(b["num_graphs"])] for o, b in
                            zip(outs, batches)]),
            np.concatenate([o[:int(b["num_graphs"])] for o, b in
                            zip(ref_outs, batches)]))
        rec = {"measured_graphs_per_s": n_packed / best,
               "policy": policy.describe(),
               "error_vs_fp32": err,
               "modeled": _modeled(conv, precision, batch_graphs,
                                   build_root)}
        out["precisions"][precision] = rec
        if log:
            m = rec["modeled"]
            log(f"{conv}/{precision}: {rec['measured_graphs_per_s']:8.0f}"
                f" graphs/s measured | modeled {m['graphs_per_s']:10.0f}"
                f" graphs/s, {m['bytes'] / 1e6:6.2f} MB | max err "
                f"{err['max_abs']:.2e} (SQNR {err['sqnr_db']:5.1f} dB)")
    base = out["precisions"]["fp32"]["modeled"]["bytes"]
    for precision in ("bf16", "int8"):
        rec = out["precisions"][precision]
        rec["modeled_bytes_ratio"] = base / rec["modeled"]["bytes"]
    return out


def run(convs=None, n_graphs: int = 64,
        batch_graphs: int = 32, repeats: int = 3, smoke: bool = False,
        build_root: str = "/tmp/gnnb_precision_bench",
        log=print) -> dict:
    if smoke:
        convs = ("gcn",)
    elif convs is None:
        convs = Cv.CONV_TYPES          # registry-derived: gat included
    res = {"dataset": "qm9", "n_graphs": n_graphs,
           "batch_graphs": batch_graphs,
           "jax_backend": jax.default_backend(),
           "bytes_floor": BYTES_FLOOR, "points": []}
    for conv in convs:
        res["points"].append(run_point(conv, n_graphs, batch_graphs,
                                       repeats, build_root, log=log))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "precision_throughput.json"),
              "w") as fh:
        json.dump(res, fh, indent=1)
    return res


def check_acceptance(res: dict):
    """Parity must hold at every precision and the low-precision paths
    must beat fp32 on modeled bytes by >= 1.5x (the smoke/CI gate; on
    TPU the measured throughput would be gated instead)."""
    for pt in res["points"]:
        precs = pt["precisions"]
        bf16, int8 = precs["bf16"], precs["int8"]
        assert bf16["error_vs_fp32"]["max_abs"] < BF16_TOL, pt["conv"]
        assert bf16["error_vs_fp32"]["sqnr_db"] > BF16_SQNR_FLOOR, \
            (pt["conv"], bf16["error_vs_fp32"])
        assert int8["error_vs_fp32"]["sqnr_db"] > INT8_SQNR_FLOOR, \
            (pt["conv"], int8["error_vs_fp32"])
        for name in ("bf16", "int8"):
            ratio = precs[name]["modeled_bytes_ratio"]
            assert ratio >= BYTES_FLOOR, (pt["conv"], name, ratio)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="gcn-only point + acceptance gates (parity per "
                         "precision, >= 1.5x modeled-bytes cut)")
    ap.add_argument("--convs", nargs="+",
                    default=list(Cv.CONV_TYPES),
                    choices=list(Cv.CONV_TYPES))
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--batch-graphs", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    res = run(tuple(args.convs), args.n, args.batch_graphs,
              args.repeats, smoke=args.smoke)
    check_acceptance(res)
    print(f"wrote {os.path.join(RESULTS, 'precision_throughput.json')} "
          f"({res['jax_backend']} backend) — acceptance OK (parity per "
          f"precision, low-precision wins modeled bytes >= "
          f"{BYTES_FLOOR}x)")
