"""Sharded packed GNN inference: throughput scaling across a device mesh.

Sweeps 1/2/4/8 data-parallel device shards. The device count must be
fixed before jax initializes, so the parent process spawns one worker
subprocess per point with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (simulated host
devices — the same mechanism the distributed tests use; on a real TPU
host the flag is unnecessary). Each worker:

* partitions the request stream into per-device shard waves
  (``pack_dataset(num_shards=)``) and runs them through the SPMD
  program from ``gnn_model.make_sharded_apply``,
* checks parity: the sharded outputs must match the single-device
  packed program shard by shard (PARITY_TOL),
* measures wave graphs/s on this host, and records the *modeled*
  sharded graphs/s from ``Project.run_synthesis`` — on CPU the
  simulated devices time-slice one socket, so the modeled figure is
  the acceptance proxy (same convention as benchmarks/fused_gather).

The parent gates near-linear modeled scaling: graphs/s at N shards must
reach ``SCALING_FLOOR * N`` times the single-device figure. JSON lands
in benchmarks/results/sharded_throughput.json.

  PYTHONPATH=src python benchmarks/sharded_throughput.py [--smoke]
      [--shards 1 2 4 8] [--n 128] [--batch-graphs 16]

``--smoke`` sweeps {1, 2} shards and enforces the parity +
modeled-scaling gates (the CI step).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results")
PARITY_TOL = 1e-4        # sharded vs single-device packed outputs
SCALING_FLOOR = 0.8      # modeled graphs/s at N shards >= 0.8 * N * 1-shard
MARK = "SHARDED_POINT_JSON:"


def _cfg():
    from repro.configs.gnn import DATASETS
    from repro.core import gnn_model as G
    ds = DATASETS["qm9"]
    return ds, G.GNNModelConfig(
        graph_input_feature_dim=ds.node_feat_dim,
        graph_input_edge_dim=ds.edge_feat_dim,
        gnn_hidden_dim=64, gnn_num_layers=2, gnn_output_dim=32,
        gnn_conv="gcn", gnn_skip_connection=True,
        avg_degree=float(ds.avg_degree),
        mlp_head=G.MLPConfig(in_dim=32 * 3, out_dim=1, hidden_dim=32,
                             hidden_layers=2))


def worker(num_shards: int, n_graphs: int, batch_graphs: int,
           repeats: int) -> dict:
    """Runs inside the subprocess whose XLA_FLAGS pinned the device
    count; measures + models one shard-count point and prints it as a
    single marked JSON line for the parent to collect."""
    import jax
    import numpy as np

    from repro.core import gnn_model as G
    from repro.core.project import Project
    from repro.data import pipeline as P
    from repro.launch.mesh import make_data_mesh
    from repro.nn import param as prm

    ds, cfg = _cfg()
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    graphs = [P.make_graph(ds, i) for i in range(n_graphs)]
    node_budget = P.size_budget(batch_graphs, ds.avg_nodes)
    edge_budget = P.size_budget(batch_graphs,
                                ds.avg_nodes * ds.avg_degree)
    waves, dropped = P.pack_dataset(graphs, node_budget, edge_budget,
                                    batch_graphs, num_shards=num_shards)
    if num_shards == 1:
        waves = [P.ShardedBatch([b], [list(range(int(b["num_graphs"])))])
                 for b in waves]
    mesh = make_data_mesh(num_shards)
    fn = G.make_sharded_apply(cfg, mesh)
    single_fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))

    # parity: each shard of the first wave vs the single-device program
    stacked0 = G.stack_shards(waves[0])
    out0 = np.asarray(fn(params, stacked0))
    max_err = 0.0
    for s, shard in enumerate(waves[0].shards):
        ref = np.asarray(single_fn(params, G.packed_to_device(shard)))
        max_err = max(max_err, float(np.abs(out0[s] - ref).max()))

    stacked = [G.stack_shards(w) for w in waves]
    for b in stacked:                                   # compile/warmup
        jax.block_until_ready(fn(params, b))
    n_served = sum(w.n_graphs for w in waves)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        outs = [fn(params, b) for b in stacked]
        jax.block_until_ready(outs)
        best = min(best, time.perf_counter() - t0)

    proj = Project(f"sharded_{num_shards}", cfg, "bench",
                   f"/tmp/gnnb_sharded_bench/{num_shards}",
                   max_nodes=ds.max_nodes, max_edges=ds.max_edges,
                   num_nodes_guess=ds.avg_nodes,
                   num_edges_guess=ds.avg_nodes * ds.avg_degree,
                   degree_guess=ds.avg_degree,
                   batch_graphs=batch_graphs, num_shards=num_shards)
    proj.gen_hw_model()
    modeled = proj.run_synthesis()["packed"]["sharded"]

    return {"num_shards": num_shards,
            "devices": len(jax.devices()),
            "n_graphs": n_served,
            "n_waves": len(waves),
            "n_dropped": len(dropped),
            "parity_max_err": max_err,
            "measured_graphs_per_s": n_served / max(best, 1e-12),
            "modeled_graphs_per_s": modeled["graphs_per_s"],
            "modeled_latency_s": modeled["latency_s"],
            "scaling_efficiency": modeled["scaling_efficiency"]}


def sweep(shard_counts, n_graphs: int, batch_graphs: int, repeats: int,
          log=print) -> dict:
    """Parent: one subprocess per shard count, XLA_FLAGS pinned."""
    points = []
    for n in shard_counts:
        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        flags = " ".join(f for f in flags.split()
                         if "xla_force_host_platform_device_count"
                         not in f)
        env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_"
                            f"device_count={n}").strip()
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src") \
            + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, os.path.abspath(__file__), "--worker",
               str(n), "--n", str(n_graphs),
               "--batch-graphs", str(batch_graphs),
               "--repeats", str(repeats)]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             timeout=900)
        line = next((ln for ln in out.stdout.splitlines()
                     if ln.startswith(MARK)), None)
        if line is None:
            raise RuntimeError(
                f"worker for {n} shards produced no result:\n"
                f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")
        pt = json.loads(line[len(MARK):])
        points.append(pt)
        if log:
            log(f"shards={n}: modeled {pt['modeled_graphs_per_s']:12.0f} "
                f"graphs/s ({pt['scaling_efficiency'] * 100:5.1f}% "
                f"scaling eff) | measured "
                f"{pt['measured_graphs_per_s']:8.0f} graphs/s "
                f"(simulated devices) | parity max err "
                f"{pt['parity_max_err']:.2e}")
    return {"dataset": "qm9", "conv": "gcn", "n_graphs": n_graphs,
            "batch_graphs": batch_graphs,
            "parity_tol": PARITY_TOL, "scaling_floor": SCALING_FLOOR,
            "points": points}


def check_acceptance(res: dict):
    """Parity at every shard count; modeled graphs/s must scale
    near-linearly (>= SCALING_FLOOR * N vs the 1-shard point)."""
    pts = {p["num_shards"]: p for p in res["points"]}
    for n, p in pts.items():
        assert p["parity_max_err"] < PARITY_TOL, (n, p["parity_max_err"])
    base = pts[1]["modeled_graphs_per_s"]
    for n, p in pts.items():
        ratio = p["modeled_graphs_per_s"] / base
        assert ratio >= SCALING_FLOOR * n, (n, ratio)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: one sweep point
    ap.add_argument("--smoke", action="store_true",
                    help="{1,2}-shard sweep + parity/scaling gates "
                         "(the CI step)")
    ap.add_argument("--shards", type=int, nargs="+",
                    default=[1, 2, 4, 8])
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--batch-graphs", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    if args.worker is not None:
        pt = worker(args.worker, args.n, args.batch_graphs, args.repeats)
        print(MARK + json.dumps(pt))
        sys.exit(0)

    counts = [1, 2] if args.smoke else args.shards
    if 1 not in counts:
        counts = [1] + counts                 # scaling baseline
    res = sweep(counts, args.n, args.batch_graphs, args.repeats)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "sharded_throughput.json")
    with open(path, "w") as fh:
        json.dump(res, fh, indent=1)
    check_acceptance(res)
    print(f"wrote {path} — acceptance OK (parity < {PARITY_TOL} at every "
          f"shard count, modeled scaling >= {SCALING_FLOOR}x linear)")
