"""Open-loop serving latency: continuous batching vs the wave drain.

The wave drain (``launch.serve.drain_gnn_queue``) reports offline batch
throughput; this benchmark measures what the ROADMAP's "millions of
users" goal actually needs — **p50/p99 request latency and sustained
graphs/s under a live arrival process**. A seeded open-loop Poisson
trace is served twice through identical executors:

* **continuous** — ``runtime.scheduler.ContinuousScheduler``: requests
  feed continuously into a partially-filled packed batch; launch on
  deadline expiry or budget-full,
* **wave** — ``runtime.scheduler.simulate_wave_drain``: the oracle of
  today's synchronous drain (collect a ``batch_graphs`` window, pack,
  run, repeat) on the same virtual timeline.

Determinism: the clock is virtual and each launch's service time is the
*modeled* packed-program latency from ``Project.run_synthesis`` (a
fixed-shape program costs the same however full the batch is, so the
constant-per-launch model is honest) — identical numbers on every run,
no sleeps. The **outputs** are the real jitted packed program's, so the
run doubles as an exactly-once parity check: every request's answer
must match the offline single-graph packed reference (PARITY_TOL), for
both schedulers.

Acceptance (``check_acceptance``, the CI ``--smoke`` gate):

* parity: every served request matches the offline reference,
* exactly-once: every request is answered exactly once,
* continuous p99 < wave p99 at every offered load,
* continuous sustained graphs/s >= THROUGHPUT_FLOOR x wave.

  PYTHONPATH=src python benchmarks/serving_latency.py [--smoke]
      [--loads 128 256 512] [--n 384] [--batch-graphs 16]
      [--deadline-ms 20]

JSON lands in benchmarks/results/serving_latency.json.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results")
PARITY_TOL = 1e-5        # scheduler outputs vs offline packed reference
THROUGHPUT_FLOOR = 0.95  # continuous graphs/s >= floor x wave graphs/s

#: full-sweep tenant mixture exercising the SLO tiers (smoke uses a
#: single default tenant so the closed gates stay trivially comparable)
TENANT_MIX = (("premium", 0.2), ("standard", 0.5), ("batch", 0.3))


def build(batch_graphs: int):
    """Model + budgets + jitted programs + modeled per-launch service."""
    import jax

    from repro.configs.gnn import DATASETS
    from repro.core import gnn_model as G
    from repro.core.project import Project
    from repro.data import pipeline as P
    from repro.nn import param as prm

    ds = DATASETS["qm9"]
    cfg = G.GNNModelConfig(
        graph_input_feature_dim=ds.node_feat_dim,
        graph_input_edge_dim=ds.edge_feat_dim,
        gnn_hidden_dim=64, gnn_num_layers=2, gnn_output_dim=32,
        gnn_conv="gcn", gnn_skip_connection=True,
        avg_degree=float(ds.avg_degree),
        mlp_head=G.MLPConfig(in_dim=32 * 3, out_dim=1, hidden_dim=32,
                             hidden_layers=2))
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    node_budget = P.size_budget(batch_graphs, ds.avg_nodes)
    edge_budget = P.size_budget(batch_graphs,
                                ds.avg_nodes * ds.avg_degree)
    fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))
    fallback = jax.jit(lambda p, el: G.apply(p, cfg, el))

    proj = Project("serving_latency", cfg, "bench",
                   "/tmp/gnnb_serving_latency",
                   max_nodes=ds.max_nodes, max_edges=ds.max_edges,
                   num_nodes_guess=ds.avg_nodes,
                   num_edges_guess=ds.avg_nodes * ds.avg_degree,
                   degree_guess=ds.avg_degree, batch_graphs=batch_graphs)
    proj.gen_hw_model()
    service_s = float(proj.run_synthesis()["packed"]["latency_s"])

    def batch_fn(batch):
        return np.asarray(jax.block_until_ready(
            fn(params, G.packed_to_device(batch))))

    def fallback_fn(g):
        el = {"node_feat": np.asarray(g.node_feat),
              "edge_index": np.asarray(g.edge_index),
              "edge_feat": np.asarray(g.edge_feat),
              "num_nodes": np.int32(g.num_nodes)}
        return np.asarray(jax.block_until_ready(fallback(params, el)))

    return {"ds": ds, "batch_fn": batch_fn, "fallback_fn": fallback_fn,
            "node_budget": node_budget, "edge_budget": edge_budget,
            "batch_graphs": batch_graphs, "service_s": service_s}


def offline_reference(env, trace):
    """Per-request oracle: each graph packed alone through the same
    jitted program (same static shapes -> same compiled program)."""
    from repro.data import pipeline as P
    refs = {}
    for i, (_, g, _) in enumerate(trace):
        batch, _ = P.pack_graphs([g], env["node_budget"],
                                 env["edge_budget"], env["batch_graphs"])
        refs[i] = env["batch_fn"](batch)[0]
    return refs


def _parity(responses, refs) -> float:
    err = 0.0
    for r in responses:
        if r.status == "served_packed" and r.output is not None:
            err = max(err, float(np.abs(r.output - refs[r.req_id]).max()))
    return err


def run_point(env, load: float, n: int, deadline_s: float, seed: int,
              tenants=(("default", 1.0),)) -> dict:
    from repro.runtime import scheduler as S
    cfg = S.SchedulerConfig(
        node_budget=env["node_budget"], edge_budget=env["edge_budget"],
        max_graphs=env["batch_graphs"], max_queue_depth=4 * n,
        tiers=S.DEFAULT_TIERS,
        default_tier=S.SLOTier("standard", deadline_s, 1))
    trace = S.poisson_trace(n, load, env["ds"], seed=seed, tenants=tenants)
    refs = offline_reference(env, trace)

    def executor():
        return S.SimExecutor(S.constant_service(env["service_s"]),
                             batch_fn=env["batch_fn"],
                             fallback_fn=env["fallback_fn"])

    cont = S.ContinuousScheduler(cfg, executor())
    S.run_trace(cont, trace)
    cs = cont.summary()
    wave_resp, ws = S.simulate_wave_drain(trace, cfg, executor())

    def ids(resps):
        return sorted(r.req_id for r in resps)

    assert ids(cont.responses) == list(range(n)), "continuous exactly-once"
    assert ids(wave_resp) == list(range(n)), "wave exactly-once"
    return {
        "load_graphs_per_s": load,
        "n_requests": n,
        "deadline_s": deadline_s,
        "parity_max_err": max(_parity(cont.responses, refs),
                              _parity(wave_resp, refs)),
        "continuous": {k: cs[k] for k in (
            "served", "fallback_served", "rejected_queue_full", "failed",
            "n_launches", "mean_batch_fill", "p50_latency_s",
            "p99_latency_s", "graphs_per_s", "per_tenant")},
        "wave": {k: ws[k] for k in (
            "served", "fallback_served", "n_launches", "mean_batch_fill",
            "p50_latency_s", "p99_latency_s", "graphs_per_s")},
    }


def sweep(loads, n: int, batch_graphs: int, deadline_ms: float,
          seed: int = 0, tenant_mix: bool = False, log=print) -> dict:
    env = build(batch_graphs)
    points = []
    for load in loads:
        pt = run_point(env, float(load), n, deadline_ms / 1e3, seed,
                       tenants=TENANT_MIX if tenant_mix
                       else (("default", 1.0),))
        points.append(pt)
        if log:
            c, w = pt["continuous"], pt["wave"]

            def ms(v):      # percentiles are None when nothing served
                return "    n/a" if v is None else f"{v * 1e3:7.2f}"
            log(f"load={load:6.0f} graphs/s | continuous p50 "
                f"{ms(c['p50_latency_s'])} ms  p99 "
                f"{ms(c['p99_latency_s'])} ms  "
                f"({c['graphs_per_s']:7.0f} graphs/s, fill "
                f"{c['mean_batch_fill'] * 100:3.0f}%) | wave p50 "
                f"{ms(w['p50_latency_s'])} ms  p99 "
                f"{ms(w['p99_latency_s'])} ms  "
                f"({w['graphs_per_s']:7.0f} graphs/s) | parity "
                f"{pt['parity_max_err']:.1e}")
    return {"dataset": "qm9", "conv": "gcn", "n_requests": n,
            "batch_graphs": batch_graphs, "deadline_ms": deadline_ms,
            "service_s": env["service_s"], "parity_tol": PARITY_TOL,
            "throughput_floor": THROUGHPUT_FLOOR, "points": points}


def check_acceptance(res: dict):
    """Parity at every load; continuous must beat the wave drain on p99
    and hold >= THROUGHPUT_FLOOR of its sustained graphs/s. Percentiles
    are explicit nulls when nothing was served, so the latency gates
    only apply after the served>0 gate passes."""
    for pt in res["points"]:
        load = pt["load_graphs_per_s"]
        assert pt["parity_max_err"] < res["parity_tol"], \
            (load, pt["parity_max_err"])
        c, w = pt["continuous"], pt["wave"]
        assert c["served"] > 0 and w["served"] > 0, \
            (load, c["served"], w["served"])
        assert c["p99_latency_s"] is not None \
            and w["p99_latency_s"] is not None, load
        assert c["p99_latency_s"] < w["p99_latency_s"], \
            (load, c["p99_latency_s"], w["p99_latency_s"])
        assert c["graphs_per_s"] >= res["throughput_floor"] \
            * w["graphs_per_s"], \
            (load, c["graphs_per_s"], w["graphs_per_s"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single-load run + parity/p99/throughput gates "
                         "(the CI step)")
    ap.add_argument("--loads", type=float, nargs="+",
                    default=[128, 256, 512])
    ap.add_argument("--n", type=int, default=384)
    ap.add_argument("--batch-graphs", type=int, default=16)
    ap.add_argument("--deadline-ms", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.smoke:
        res = sweep([256], 160, 16, args.deadline_ms, args.seed)
    else:
        res = sweep(args.loads, args.n, args.batch_graphs,
                    args.deadline_ms, args.seed, tenant_mix=True)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "serving_latency.json")
    with open(path, "w") as fh:
        json.dump(res, fh, indent=1)
    check_acceptance(res)
    print(f"wrote {path} — acceptance OK (parity < {PARITY_TOL}, "
          f"continuous p99 < wave p99 and graphs/s >= "
          f"{THROUGHPUT_FLOOR}x wave at every offered load)")
