"""Roofline tables from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Emits benchmarks/results/roofline_<mesh>.md + a machine-readable JSON.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.roofline import load_cells, markdown_table

RESULTS = os.path.join(os.path.dirname(__file__), "results")
DRYRUN = os.path.join(RESULTS, "dryrun")


BASELINE_DIR = os.path.join(RESULTS, "dryrun_baseline")


def run(log=print) -> dict:
    variants = []
    if os.path.isdir(BASELINE_DIR):
        variants.append(("baseline", BASELINE_DIR, "auto"))
    variants.append(("optimized", DRYRUN, "optimized"))
    cells = []
    for tag, path, variant in variants:
        sub = load_cells(path, variant=variant)
        if not sub and variant == "optimized":
            sub = load_cells(path, variant="auto")   # pre-optimized runs
        for mesh in ("16x16", "2x16x16"):
            md = markdown_table(sub, mesh)
            out = os.path.join(RESULTS, f"roofline_{mesh}_{tag}.md")
            with open(out, "w") as f:
                f.write(f"# Roofline — mesh {mesh} — {tag} presets\n\n"
                        + md)
        cells += sub
    if not cells:
        if log:
            log("no dry-run results found — run "
                "`python -m repro.launch.dryrun --all --both-meshes`")
        return {"cells": 0}
    with open(os.path.join(RESULTS, "roofline_cells.json"), "w") as f:
        json.dump([dataclasses.asdict(c) for c in cells], f, indent=1)
    ok = [c for c in cells if c.ok]
    train16 = [c for c in ok if c.mesh == "16x16"
               and c.shape == "train_4k" and c.variant == "optimized"]
    if log:
        log(f"{len(ok)}/{len(cells)} cell records ok; optimized "
            f"single-pod train_4k roofline fractions: " + ", ".join(
                f"{c.arch}={c.roofline_fraction * 100:.0f}%"
                for c in sorted(train16, key=lambda c: c.arch)))
    return {"cells": len(cells), "ok": len(ok)}


if __name__ == "__main__":
    run()
