"""Paper Fig. 7: resource usage of base vs parallel generated designs.

FPGA resources (BRAM/DSP/LUT) map to: HBM bytes per device (weights +
buffers), VMEM working set of the tiled kernels (BlockSpec footprint),
and MXU occupancy proxy (tile area / 128^2).
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.configs.gnn import DATASETS, FPX_BASE, FPX_PARALLEL, \
    benchmark_config
from repro.core import gnn_model as G
from repro.core.project import Project, TPUTarget
from repro.kernels.tiled_linear.ops import blocks_from_parallelism
from repro.nn import param as prm

RESULTS = os.path.join(os.path.dirname(__file__), "results")
CONVS = ("gcn", "gin", "pna", "sage")


def vmem_tile_bytes(p_in: int, p_out: int, block_m: int = 128) -> int:
    bk, bn = blocks_from_parallelism(p_in, p_out)
    return 4 * (block_m * bk + bk * bn + block_m * bn)


def run(log=print) -> dict:
    os.makedirs(RESULTS, exist_ok=True)
    target = TPUTarget()
    rows = []
    for conv in CONVS:
        for parallel in (False, True):
            cfg = benchmark_config(conv, "qm9", parallel=parallel)
            fpx = FPX_PARALLEL if parallel else FPX_BASE
            proj = Project(f"res_{conv}_{parallel}", cfg, "res",
                           f"/tmp/gnnb_res", dataset_cfg=DATASETS["qm9"],
                           float_or_fixed="fixed", fpx=fpx)
            proj.gen_hw_model()
            rep = proj.run_synthesis()
            n_params = prm.count_params(G.model_plan(cfg))
            vmem = vmem_tile_bytes(cfg.gnn_p_hidden, cfg.gnn_p_out)
            rows.append({
                "conv": conv,
                "variant": "parallel" if parallel else "base",
                "params": n_params,
                "weight_bytes": n_params * fpx.w // 8,
                "hbm_bytes": rep["hbm_total_bytes"],
                "hbm_util_pct": 100 * rep["hbm_total_bytes"]
                / target.hbm_bytes,
                "vmem_tile_bytes": vmem,
                "vmem_util_pct": 100 * vmem / target.vmem_bytes,
                "mxu_tile_occupancy_pct": 100 * min(
                    cfg.gnn_p_hidden * cfg.gnn_p_out, 128) / 128,
                "flops": rep["flops"],
            })
            if log:
                r = rows[-1]
                log(f"  {conv:5s} {r['variant']:8s} "
                    f"hbm {r['hbm_util_pct']:.2f}% "
                    f"vmem-tile {r['vmem_util_pct']:.1f}% "
                    f"mxu-occ {r['mxu_tile_occupancy_pct']:.0f}%")
    res = {"rows": rows,
           "note": ("parallel designs use larger tiles (higher VMEM/MXU "
                    "utilization) and <16,10> weights (half the HBM of "
                    "<32,16> base) — the Fig. 7 'headroom remains' "
                    "observation holds: utilization stays well below "
                    "budget, so parallelism can be raised further")}
    with open(os.path.join(RESULTS, "resources.json"), "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    run()
