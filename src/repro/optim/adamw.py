"""AdamW + cosine schedule + global-norm clipping, plan-aware.

Optimizer moments are declared as a *plan* (fp32, same logical axes as the
parameters) so the dry-run can lower a full train_step — params, grads and
moments all sharded by the same rules table (FSDP+TP by default, which is
ZeRO-ish sharding of the fp32 state for free).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec, tree_map_specs


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # bf16 moments for >=100B-param models (8-bit-Adam-style state
    # compression; fp32 Adam state for jamba-398B alone would be 3.2 TB).
    moment_dtype: str = "float32"


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def opt_plan(param_plan, cfg: OptConfig = OptConfig()):
    """Plan for optimizer state: m/v mirroring the parameter axes."""
    dt = jnp.dtype(cfg.moment_dtype)
    mk = lambda s: ParamSpec(s.shape, dt, s.axes, init="zeros")
    return {"m": tree_map_specs(mk, param_plan),
            "v": tree_map_specs(mk, param_plan),
            "step": ParamSpec((), jnp.int32, (), init="zeros")}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def apply_updates(cfg: OptConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh, vh = mf / bc1, vf / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mf.astype(m.dtype), vf.astype(v.dtype))

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
