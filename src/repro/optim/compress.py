"""int8 gradient compression with error feedback.

Compresses gradients to int8 (per-tensor symmetric scale) before the
data-parallel all-reduce and decompresses after, carrying the quantization
residual to the next step (error feedback keeps SGD/Adam convergence).
Under pjit the all-reduce is implicit (GSPMD inserts it for the batch-mean);
``compressed_mean`` makes the wire format explicit via shard_map for the
benchmark/tests path, and ``ef_quantize``/``ef_restore`` are used inside the
train step around the implicit reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    a = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(a, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_quantize(grads, errors):
    """Quantize (grads + carried error); returns (q_tree, scales, new_errors)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return q, s, corrected - deq

    flat = jax.tree_util.tree_map(one, grads, errors)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], flat, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), pick(1), pick(2)


def ef_restore(q_tree, scales):
    return jax.tree_util.tree_map(dequantize_int8, q_tree, scales)


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_mean(grads, errors, axis_name: str):
    """Explicit compressed all-reduce for use inside shard_map: int8 on the
    wire (sum of int32 accumulators + per-shard scales), error feedback on
    the residual. 4x wire-bytes reduction vs f32, 2x vs bf16."""
    q, s, new_err = ef_quantize(grads, errors)
    n = jax.lax.psum(1, axis_name)
    summed = jax.tree_util.tree_map(
        lambda qi, si: jax.lax.psum(qi.astype(jnp.int32).astype(jnp.float32)
                                    * si, axis_name) / n, q, s)
    return summed, new_err
