"""minitron-4b [dense]: 32L d3072 24H (GQA kv=8) ff9216 v256000 — pruned
nemotron. 24 heads pad to 32 for 16-way TP. [arXiv:2407.14679]"""
from repro.configs.common import dense_lm
from repro.models.lm import LMConfig
import dataclasses


def config() -> LMConfig:
    return dense_lm("minitron-4b", layers=32, d_model=3072, heads=24, kv=8,
                    d_ff=9216, vocab=256000)


def reduced() -> LMConfig:
    return dataclasses.replace(
        dense_lm("minitron-4b-smoke", layers=2, d_model=48, heads=3, kv=1,
                 d_ff=144, vocab=512, head_dim=16), xent_chunk=32)
