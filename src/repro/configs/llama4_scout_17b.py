"""llama4-scout-17b-a16e [moe]: 48L d5120 40H (GQA kv=8) MoE 16e top-1 +
shared expert (ff 8192). 40 heads pad to 48 for 16-way TP.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.common import gqa
from repro.models.lm import LMConfig
from repro.nn.moe import MoEConfig


def config() -> LMConfig:
    return LMConfig(
        name="llama4-scout-17b-a16e", family="moe", d_model=5120,
        vocab_size=202048,
        superblock=(("attn", "moe"),), repeat=48,
        attn=gqa(5120, 40, 8, 128),
        moe=MoEConfig(d_model=5120, num_experts=16, top_k=1,
                      d_ff_expert=8192, num_shared_experts=1,
                      d_ff_shared=8192),
        d_ff=8192, grad_accum=4)


def reduced() -> LMConfig:
    return LMConfig(
        name="llama4-scout-smoke", family="moe", d_model=64, vocab_size=256,
        superblock=(("attn", "moe"),), repeat=2,
        attn=gqa(64, 4, 2, 16),
        moe=MoEConfig(d_model=64, num_experts=4, top_k=1, d_ff_expert=32,
                      num_shared_experts=1, d_ff_shared=32),
        d_ff=32, xent_chunk=32)
