"""Shared helpers for architecture configs.

Every arch module exports ``config()`` (the paper-exact full config) and
``reduced()`` (a small same-family config for CPU smoke tests). Head counts
that do not divide the 16-way model axis carry ``num_heads_padded``
(Megatron-style TP constraint; overhead is charged in the roofline).
"""
from __future__ import annotations

import dataclasses

from repro.models.lm import LMConfig, EncoderConfig
from repro.nn.attention import AttnConfig, MLAConfig
from repro.nn.mamba import MambaConfig
from repro.nn.moe import MoEConfig
from repro.nn.rwkv import RWKVConfig

TP = 16  # model-axis width of the production mesh


def pad_heads(h: int, tp: int = TP) -> int:
    return h if h % tp == 0 else -(-h // tp) * tp


def gqa(d_model: int, heads: int, kv: int, head_dim: int = 128,
        qk_norm: bool = False, rope_theta: float = 1e6,
        chunk: int = 1024) -> AttnConfig:
    return AttnConfig(d_model=d_model, num_heads=heads, num_kv_heads=kv,
                      head_dim=head_dim, num_heads_padded=pad_heads(heads),
                      qk_norm=qk_norm, rope_theta=rope_theta, chunk=chunk)


def dense_lm(name: str, *, layers: int, d_model: int, heads: int, kv: int,
             d_ff: int, vocab: int, qk_norm: bool = False,
             head_dim: int = 128) -> LMConfig:
    return LMConfig(
        name=name, family="dense", d_model=d_model, vocab_size=vocab,
        superblock=(("attn", "mlp"),), repeat=layers,
        attn=gqa(d_model, heads, kv, head_dim, qk_norm), d_ff=d_ff)


# Assigned input-shape grid (seq_len, global_batch, step kind).
SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def applicable_shapes(cfg: LMConfig) -> list:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")   # ssm/hybrid only (see DESIGN.md)
    return names


def reduce_common(cfg: LMConfig, **kw) -> LMConfig:
    return dataclasses.replace(cfg, **kw)
