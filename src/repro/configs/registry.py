"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

import importlib

# arch id -> module path (each exports config() and reduced())
ARCHS = {
    "qwen3-8b": "repro.configs.qwen3_8b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "minitron-4b": "repro.configs.minitron_4b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b",
    "jamba-1.5-large-398b": "repro.configs.jamba_15_large",
    "whisper-base": "repro.configs.whisper_base",
    "rwkv6-1.6b": "repro.configs.rwkv6_16b",
}

# The paper's own GNN workloads (GNNBuilder Table II models).
GNN_ARCHS = {
    "gnnb-gcn": ("gcn",),
    "gnnb-sage": ("sage",),
    "gnnb-gin": ("gin",),
    "gnnb-pna": ("pna",),
}


def get_config(arch: str, reduced: bool = False):
    if arch in GNN_ARCHS:
        from repro.configs import gnn
        return gnn.config(GNN_ARCHS[arch][0], reduced=reduced)
    mod = importlib.import_module(ARCHS[arch])
    return mod.reduced() if reduced else mod.config()


def list_archs() -> list:
    return list(ARCHS) + list(GNN_ARCHS)
