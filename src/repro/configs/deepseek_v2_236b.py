"""deepseek-v2-236b [moe]: 60L d5120 128H, MLA kv_lora=512, MoE 2 shared +
160 routed top-6 (expert ff 1536); first layer dense (ff 12288).
[arXiv:2405.04434]"""
from repro.models.lm import LMConfig
from repro.nn.attention import MLAConfig
from repro.nn.moe import MoEConfig


def config() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b", family="moe", d_model=5120,
        vocab_size=102400,
        prefix=(("mla", "mlp"),),
        superblock=(("mla", "moe"),), repeat=59,
        mla=MLAConfig(d_model=5120, num_heads=128, kv_lora=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(d_model=5120, num_experts=160, top_k=6,
                      d_ff_expert=1536, num_shared_experts=2,
                      d_ff_shared=3072),
        d_ff=12288, grad_accum=4)


def reduced() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b-smoke", family="moe", d_model=64,
        vocab_size=256,
        prefix=(("mla", "mlp"),),
        superblock=(("mla", "moe"),), repeat=2,
        mla=MLAConfig(d_model=64, num_heads=4, kv_lora=32, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(d_model=64, num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared_experts=1, d_ff_shared=64),
        d_ff=128, xent_chunk=32)
