"""internlm2-20b [dense]: 48L d6144 48H (GQA kv=8) ff16384 v92544.
[arXiv:2403.17297]"""
from repro.configs.common import dense_lm
from repro.models.lm import LMConfig
import dataclasses


def config() -> LMConfig:
    return dense_lm("internlm2-20b", layers=48, d_model=6144, heads=48,
                    kv=8, d_ff=16384, vocab=92544)


def reduced() -> LMConfig:
    return dataclasses.replace(
        dense_lm("internlm2-20b-smoke", layers=2, d_model=48, heads=6, kv=2,
                 d_ff=96, vocab=256, head_dim=8), xent_chunk=32)
