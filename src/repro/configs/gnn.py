"""The paper's own GNN workloads (Table II models, §VIII-B benchmark).

``benchmark_config(conv, parallel)`` reproduces the §VIII-B setup:
FPGA-Parallel uses gnn_p_in=1 / p_hidden=16 / p_out=8, MLP 8/8/1 and
<16,10> fixed point; FPGA-Base uses all-1 parallelism and <32,16>.
Dataset statistics mirror the five MoleculeNet graph-level tasks.
"""
from __future__ import annotations

from repro.core.gnn_model import GNNModelConfig, MLPConfig
from repro.core.quantization import FPX
from repro.data.pipeline import GraphDataConfig

# synthetic stand-ins matched to MoleculeNet size statistics
DATASETS = {
    "qm9": GraphDataConfig(avg_nodes=18, avg_degree=2, node_feat_dim=11,
                           edge_feat_dim=4, seed=9),
    "esol": GraphDataConfig(avg_nodes=13, avg_degree=2, node_feat_dim=9,
                            edge_feat_dim=3, seed=10),
    "freesolv": GraphDataConfig(avg_nodes=8, avg_degree=2, node_feat_dim=9,
                                edge_feat_dim=3, seed=11),
    "lipophilicity": GraphDataConfig(avg_nodes=27, avg_degree=2,
                                     node_feat_dim=9, edge_feat_dim=3,
                                     seed=12),
    "hiv": GraphDataConfig(avg_nodes=25, avg_degree=2, node_feat_dim=9,
                           edge_feat_dim=3, seed=13),
}

FPX_PARALLEL = FPX(16, 10)   # paper: <16,10> for FPGA-Parallel
FPX_BASE = FPX(32, 16)       # paper: <32,16> for FPGA-Base


def benchmark_config(conv: str, dataset: str = "qm9",
                     parallel: bool = True) -> GNNModelConfig:
    ds = DATASETS[dataset]
    if parallel:
        gp = dict(gnn_p_in=1, gnn_p_hidden=16, gnn_p_out=8)
        mp = dict(p_in=8, p_hidden=8, p_out=1)
    else:
        gp = dict(gnn_p_in=1, gnn_p_hidden=1, gnn_p_out=1)
        mp = dict(p_in=1, p_hidden=1, p_out=1)
    if conv == "pna":  # paper: PNA uses p_hidden=8, p_out=8
        if parallel:
            gp = dict(gnn_p_in=1, gnn_p_hidden=8, gnn_p_out=8)
    return GNNModelConfig(
        graph_input_feature_dim=ds.node_feat_dim,
        graph_input_edge_dim=ds.edge_feat_dim,
        gnn_hidden_dim=128, gnn_num_layers=2, gnn_output_dim=64,
        gnn_conv=conv, gnn_activation="relu", gnn_skip_connection=True,
        global_pooling=("add", "mean", "max"),
        mlp_head=MLPConfig(in_dim=64 * 3, out_dim=ds.num_targets,
                           hidden_dim=64, hidden_layers=3,
                           activation="relu", **mp),
        **gp)


def config(conv: str, reduced: bool = False) -> GNNModelConfig:
    if reduced:
        ds = DATASETS["qm9"]
        return GNNModelConfig(
            graph_input_feature_dim=ds.node_feat_dim,
            graph_input_edge_dim=ds.edge_feat_dim,
            gnn_hidden_dim=16, gnn_num_layers=2, gnn_output_dim=8,
            gnn_conv=conv, gnn_skip_connection=True,
            mlp_head=MLPConfig(in_dim=24, out_dim=1, hidden_dim=8,
                               hidden_layers=1))
    return benchmark_config(conv)
