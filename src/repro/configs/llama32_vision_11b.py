"""llama-3.2-vision-11b [vlm]: 40L d4096 32H (GQA kv=8) ff14336 v128256,
cross-attn image layers (every 5th layer; period-5 superblock x8). The
vision frontend is a STUB: input_specs supplies precomputed patch
embeddings (2048 tokens x 1280). [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.common import gqa
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
import dataclasses

SUPERBLOCK = (("xattn", "mlp"),) + (("attn", "mlp"),) * 4


def config() -> LMConfig:
    return LMConfig(
        name="llama-3.2-vision-11b", family="vlm", d_model=4096,
        vocab_size=128256, superblock=SUPERBLOCK, repeat=8,
        attn=gqa(4096, 32, 8, 128), d_ff=14336,
        num_mem_tokens=2048, mem_dim=1280)


def reduced() -> LMConfig:
    return LMConfig(
        name="llama-3.2-vision-11b-smoke", family="vlm", d_model=64,
        vocab_size=256, superblock=(("xattn", "mlp"), ("attn", "mlp")),
        repeat=2, attn=gqa(64, 4, 2, 16), d_ff=128,
        num_mem_tokens=16, mem_dim=24, xent_chunk=32)
