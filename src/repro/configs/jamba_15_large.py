"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H (GQA kv=8) ff24576, Mamba +
attention 1:7 interleave, MoE 16e top-2 on alternate layers. Period-8
superblock (attn at row 4) x9. Sub-quadratic => long_500k applies.
[arXiv:2403.19887]"""
from repro.configs.common import gqa
from repro.models.lm import LMConfig
from repro.nn.mamba import MambaConfig
from repro.nn.moe import MoEConfig

SUPERBLOCK = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
    ("attn", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)


def config() -> LMConfig:
    return LMConfig(
        name="jamba-1.5-large-398b", family="hybrid", d_model=8192,
        vocab_size=65536, superblock=SUPERBLOCK, repeat=9,
        attn=gqa(8192, 64, 8, 128),
        mamba=MambaConfig(d_model=8192, expand=2, d_state=16, d_conv=4,
                          chunk=128),
        moe=MoEConfig(d_model=8192, num_experts=16, top_k=2,
                      d_ff_expert=24576),
        d_ff=24576, sub_quadratic=True, grad_accum=8)


def reduced() -> LMConfig:
    return LMConfig(
        name="jamba-smoke", family="hybrid", d_model=64, vocab_size=256,
        superblock=(("mamba", "moe"), ("attn", "mlp")), repeat=2,
        attn=gqa(64, 4, 2, 16),
        mamba=MambaConfig(d_model=64, expand=2, d_state=4, d_conv=4,
                          chunk=16),
        moe=MoEConfig(d_model=64, num_experts=4, top_k=2, d_ff_expert=32),
        d_ff=128, sub_quadratic=True, xent_chunk=32)
