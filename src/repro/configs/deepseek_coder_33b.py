"""deepseek-coder-33b [dense]: 62L d7168 56H (GQA kv=8) ff19200 v32256 —
llama-arch. 56 heads pad to 64 for 16-way TP. [arXiv:2401.14196]"""
from repro.configs.common import dense_lm
from repro.models.lm import LMConfig
import dataclasses


def config() -> LMConfig:
    return dense_lm("deepseek-coder-33b", layers=62, d_model=7168, heads=56,
                    kv=8, d_ff=19200, vocab=32256)


def reduced() -> LMConfig:
    return dataclasses.replace(
        dense_lm("deepseek-coder-33b-smoke", layers=3, d_model=56, heads=7,
                 kv=1, d_ff=160, vocab=256, head_dim=8), xent_chunk=32)
