"""rwkv6-1.6b [ssm]: 24L d2048 (attn-free) ff7168 v65536 — Finch,
data-dependent decay. Sub-quadratic => long_500k applies.
[arXiv:2404.05892]"""
from repro.models.lm import LMConfig
from repro.nn.rwkv import RWKVConfig


def config() -> LMConfig:
    return LMConfig(
        name="rwkv6-1.6b", family="ssm", d_model=2048, vocab_size=65536,
        superblock=(("rwkv", "cmix"),), repeat=24,
        rwkv=RWKVConfig(d_model=2048, head_dim=64, d_ff=7168),
        norm="layernorm", sub_quadratic=True)


def reduced() -> LMConfig:
    return LMConfig(
        name="rwkv6-smoke", family="ssm", d_model=64, vocab_size=256,
        superblock=(("rwkv", "cmix"),), repeat=2,
        rwkv=RWKVConfig(d_model=64, head_dim=16, d_ff=224, decay_lora=16),
        norm="layernorm", sub_quadratic=True, xent_chunk=32)
