"""whisper-base [audio]: 6L enc + 6L dec, d512 8H ff2048 v51865. Conv
frontend is a STUB — input_specs supplies precomputed frame embeddings
(B, seq, d_model); decoder length = seq // 4. 8 heads pad to 16 for TP.
[arXiv:2212.04356]"""
from repro.configs.common import gqa
from repro.models.lm import LMConfig, EncoderConfig

DEC_SUPERBLOCK = (("attn", None), ("xattn", None), (None, "mlp"))


def config() -> LMConfig:
    return LMConfig(
        name="whisper-base", family="audio", d_model=512, vocab_size=51865,
        superblock=DEC_SUPERBLOCK, repeat=6,
        encoder=EncoderConfig(superblock=(("attn_bidir", "mlp"),), repeat=6),
        attn=gqa(512, 8, 8, 64), d_ff=2048,
        num_mem_tokens=1, mem_dim=512, dec_len_ratio=4, norm="layernorm")


def reduced() -> LMConfig:
    return LMConfig(
        name="whisper-base-smoke", family="audio", d_model=32,
        vocab_size=128, superblock=DEC_SUPERBLOCK, repeat=2,
        encoder=EncoderConfig(superblock=(("attn_bidir", "mlp"),), repeat=2),
        attn=gqa(32, 4, 4, 8), d_ff=64,
        num_mem_tokens=1, mem_dim=32, dec_len_ratio=4, norm="layernorm",
        xent_chunk=16)
