"""qwen3-8b [dense]: 36L d4096 32H (GQA kv=8) ff12288 v151936, qk-norm.
[hf:Qwen/Qwen3-8B]"""
from repro.configs.common import dense_lm, gqa
from repro.models.lm import LMConfig
import dataclasses


def config() -> LMConfig:
    return dense_lm("qwen3-8b", layers=36, d_model=4096, heads=32, kv=8,
                    d_ff=12288, vocab=151936, qk_norm=True)


def reduced() -> LMConfig:
    return dataclasses.replace(
        dense_lm("qwen3-8b-smoke", layers=2, d_model=64, heads=4, kv=2,
                 d_ff=128, vocab=256, qk_norm=True, head_dim=16),
        xent_chunk=32)
