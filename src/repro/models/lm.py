"""Generic block-program LM covering every assigned architecture.

A model is a *superblock* — a tuple of (mixer, ffn) rows — scanned
``repeat`` times (plus optional unscanned ``prefix`` rows). Mixers:
``attn`` / ``attn_bidir`` / ``xattn`` / ``mla`` / ``mamba`` / ``rwkv`` /
``None``; FFNs: ``mlp`` / ``moe`` / ``cmix`` / ``None``. Scanning keeps the
HLO size independent of depth, which is what makes 512-device dry-run
compiles tractable and is also the production-correct choice (compile time,
cache pressure).

All functions are pure; parameters come from ``model_plan`` (see
``nn.param``), decode caches from ``cache_plan``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import attention as A
from repro.nn import mamba as M
from repro.nn import moe as MOE
from repro.nn import rwkv as R
from repro.nn.layers import (chunked_softmax_xent, embed, embedding_plan,
                             layernorm, layernorm_plan, linear, linear_plan,
                             mlp, mlp_plan, rmsnorm, rmsnorm_plan)
from repro.nn.param import ParamSpec, stack_plan

Row = tuple  # (mixer_kind | None, ffn_kind | None)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    superblock: tuple
    repeat: int


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    d_model: int
    vocab_size: int
    superblock: tuple             # tuple[Row, ...]
    repeat: int
    prefix: tuple = ()            # unscanned leading rows
    attn: A.AttnConfig | None = None
    mla: A.MLAConfig | None = None
    moe: MOE.MoEConfig | None = None
    mamba: M.MambaConfig | None = None
    rwkv: R.RWKVConfig | None = None
    d_ff: int = 0
    activation: str = "silu"
    norm: str = "rmsnorm"
    encoder: EncoderConfig | None = None
    num_mem_tokens: int = 0       # vlm image patches / set >0 to enable mem
    mem_dim: int = 0              # raw frontend embedding width
    dec_len_ratio: int = 1        # enc-dec: decoder_len = seq // ratio
    xent_chunk: int = 1024
    remat: str = "full"           # none | dots | full
    grad_accum: int = 1           # microbatches per train step
    aux_loss_weight: float = 0.01
    sub_quadratic: bool = False   # supports long_500k
    dtype: Any = jnp.bfloat16

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + self.repeat * len(self.superblock)


# ================================================================= plans ==
def _norm_plan(cfg: LMConfig):
    return (rmsnorm_plan(cfg.d_model, cfg.dtype, "embed")
            if cfg.norm == "rmsnorm"
            else layernorm_plan(cfg.d_model, cfg.dtype, "embed"))


def _apply_norm(cfg: LMConfig, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


def _mixer_plan(cfg: LMConfig, kind: str):
    if kind in ("attn", "attn_bidir"):
        return A.attn_plan(cfg.attn, cfg.dtype)
    if kind == "xattn":
        return A.xattn_plan(cfg.attn, cfg.d_model, cfg.dtype)
    if kind == "mla":
        return A.mla_plan(cfg.mla, cfg.dtype)
    if kind == "mamba":
        return M.mamba_plan(cfg.mamba, cfg.dtype)
    if kind == "rwkv":
        return R.time_mix_plan(cfg.rwkv, cfg.dtype)
    raise ValueError(kind)


def _ffn_plan(cfg: LMConfig, kind: str):
    if kind == "mlp":
        return mlp_plan(cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
    if kind == "moe":
        return MOE.moe_plan(cfg.moe, cfg.dtype)
    if kind == "cmix":
        return R.channel_mix_plan(cfg.rwkv, cfg.dtype)
    raise ValueError(kind)


def _row_plan(cfg: LMConfig, row: Row):
    mixer, ffn = row
    p = {}
    if mixer is not None:
        p["norm1"] = _norm_plan(cfg)
        p["mixer"] = _mixer_plan(cfg, mixer)
    if ffn is not None:
        p["norm2"] = _norm_plan(cfg)
        p["ffn"] = _ffn_plan(cfg, ffn)
    return p


def _stack_rows(cfg: LMConfig, rows: tuple):
    return {f"r{i}": _row_plan(cfg, row) for i, row in enumerate(rows)}


def model_plan(cfg: LMConfig):
    plan = {
        "embed": embedding_plan(cfg.vocab_size, cfg.d_model, cfg.dtype),
        "blocks": stack_plan(_stack_rows(cfg, cfg.superblock), cfg.repeat),
        "final_norm": _norm_plan(cfg),
        "out": linear_plan(cfg.d_model, cfg.vocab_size, in_axis="embed",
                           out_axis="vocab", dtype=cfg.dtype),
    }
    if cfg.prefix:
        plan["prefix"] = {f"p{i}": _row_plan(cfg, row)
                          for i, row in enumerate(cfg.prefix)}
    if cfg.encoder is not None:
        plan["encoder"] = {
            "blocks": stack_plan(_stack_rows(cfg, cfg.encoder.superblock),
                                 cfg.encoder.repeat),
            "final_norm": _norm_plan(cfg),
        }
    if cfg.num_mem_tokens:
        plan["mem_proj"] = linear_plan(cfg.mem_dim or cfg.d_model,
                                       cfg.d_model, in_axis=None,
                                       out_axis="embed", dtype=cfg.dtype)
    return plan


def _row_cache_plan(cfg: LMConfig, row: Row, batch: int, seq: int,
                    mem_len: int, seq_axis: str):
    mixer, ffn = row
    c = {}
    if mixer in ("attn", "attn_bidir"):
        kv, hd = cfg.attn.num_kv_heads, cfg.attn.head_dim
        shp, ax = (batch, seq, kv, hd), ("batch", seq_axis, None, None)
        c["k"] = ParamSpec(shp, cfg.dtype, ax, init="zeros")
        c["v"] = ParamSpec(shp, cfg.dtype, ax, init="zeros")
    elif mixer == "xattn":
        kv, hd = cfg.attn.num_kv_heads, cfg.attn.head_dim
        shp, ax = (batch, mem_len, kv, hd), ("batch", seq_axis, None, None)
        c["mk"] = ParamSpec(shp, cfg.dtype, ax, init="zeros")
        c["mv"] = ParamSpec(shp, cfg.dtype, ax, init="zeros")
    elif mixer == "mla":
        c["c"] = ParamSpec((batch, seq, cfg.mla.cache_dim), cfg.dtype,
                           ("batch", seq_axis, None), init="zeros")
    elif mixer == "mamba":
        m = cfg.mamba
        c["conv"] = ParamSpec((batch, m.d_inner, m.d_conv - 1), cfg.dtype,
                              ("batch", "state", None), init="zeros")
        c["ssm"] = ParamSpec((batch, m.d_inner, m.d_state), jnp.float32,
                             ("batch", "state", None), init="zeros")
    elif mixer == "rwkv":
        r = cfg.rwkv
        c["state"] = ParamSpec((batch, r.num_heads, r.head_dim, r.head_dim),
                               jnp.float32, ("batch", "heads", None, None),
                               init="zeros")
        c["tm_last"] = ParamSpec((batch, cfg.d_model), cfg.dtype,
                                 ("batch", "embed"), init="zeros")
    if ffn == "cmix":
        c["cm_last"] = ParamSpec((batch, cfg.d_model), cfg.dtype,
                                 ("batch", "embed"), init="zeros")
    return c


def cache_plan(cfg: LMConfig, batch: int, seq: int, mem_len: int = 0,
               seq_axis: str = "kv_seq"):
    """Decode-cache spec tree (ParamSpecs -> abstract()/materialize())."""
    plan = {"blocks": stack_plan(
        {f"r{i}": _row_cache_plan(cfg, row, batch, seq, mem_len, seq_axis)
         for i, row in enumerate(cfg.superblock)}, cfg.repeat)}
    if cfg.prefix:
        plan["prefix"] = {
            f"p{i}": _row_cache_plan(cfg, row, batch, seq, mem_len, seq_axis)
            for i, row in enumerate(cfg.prefix)}
    return plan


# =============================================================== forward ==
def _bidir(cfg: LMConfig) -> A.AttnConfig:
    return dataclasses.replace(cfg.attn, causal=False)


def _apply_row(cfg: LMConfig, row: Row, p, x, positions, mem,
               constrain) -> tuple:
    """Full-sequence row application. Returns (x, cache, aux)."""
    mixer, ffn = row
    cache, aux = {}, jnp.zeros((), jnp.float32)
    # Megatron-SP boundary: 'mixer_seq' rules decide whether the sequence
    # is gathered before the mixer/ffn matmuls (SP+TP) or stays sharded
    # with weights gathered instead (fsdp_seq preset). NOTE: fusing the
    # gather region across mixer+ffn (gather once per row) was tried and
    # MEASURED WORSE (+30% collectives on deepseek-v2/jamba train — the
    # full-domain residual adds force extra reshards); see §Perf.
    gather_seq = lambda t: constrain(t, ("batch", "mixer_seq", None))
    scatter_seq = lambda t: constrain(t, ("batch", "act_seq", "embed"))
    # nested remat: each mixer/ffn is its own checkpoint region, so the
    # backward pass holds one sub-block's intermediates at a time instead
    # of a whole superblock's (jamba: 8 rows/superblock).
    ckpt = jax.checkpoint if cfg.remat != "none" else (lambda f: f)
    if mixer is not None:
        h = gather_seq(_apply_norm(cfg, p["norm1"], x))

        def run_mixer(p_m, h):
            if mixer == "attn":
                y, (k, v) = A.attn_forward(p_m, h, cfg.attn, positions,
                                           constrain)
                return y, {"k": k, "v": v}
            if mixer == "attn_bidir":
                y, _ = A.attn_forward(p_m, h, _bidir(cfg), positions,
                                      constrain)
                return y, {}
            if mixer == "xattn":
                mk, mv = A.xattn_kv(p_m, mem, cfg.attn)
                y = A.xattn_forward(p_m, h, (mk, mv), cfg.attn, constrain)
                return y, {"mk": mk, "mv": mv}
            if mixer == "mla":
                y, c = A.mla_forward(p_m, h, cfg.mla, positions, constrain)
                return y, {"c": c}
            if mixer == "mamba":
                y, (conv, ssm) = M.mamba_forward(p_m, h, cfg.mamba,
                                                 constrain)
                return y, {"conv": conv, "ssm": ssm}
            if mixer == "rwkv":
                y, (state, last) = R.time_mix_forward(p_m, h, cfg.rwkv,
                                                      constrain=constrain)
                return y, {"state": state, "tm_last": last}
            raise ValueError(mixer)

        y, cache = ckpt(run_mixer)(p["mixer"], h)
        x = x + scatter_seq(y)
    if ffn is not None:
        h = gather_seq(_apply_norm(cfg, p["norm2"], x))

        def run_ffn(p_f, h):
            if ffn == "mlp":
                return mlp(p_f, h, cfg.activation), {}, \
                    jnp.zeros((), jnp.float32)
            if ffn == "moe":
                y, aux = MOE.moe_forward(p_f, h, cfg.moe, constrain)
                return y, {}, aux
            if ffn == "cmix":
                y, cm_last = R.channel_mix_forward(p_f, h)
                return y, {"cm_last": cm_last}, jnp.zeros((), jnp.float32)
            raise ValueError(ffn)

        y, extra, aux = ckpt(run_ffn)(p["ffn"], h)
        cache.update(extra)
        x = x + scatter_seq(y)
    return x, cache, aux


def _remat(cfg: LMConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def _run_encoder(params, cfg: LMConfig, frames, constrain):
    enc = params["encoder"]
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, layer):
        for i, row in enumerate(cfg.encoder.superblock):
            x, _, _ = _apply_row(cfg, row, layer[f"r{i}"], x, positions,
                                 None, constrain)
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), frames.astype(cfg.dtype),
                        enc["blocks"])
    return _apply_norm(cfg, enc["final_norm"], x)


def forward(params, cfg: LMConfig, ids, mem=None, *, constrain=A.NO_CONSTRAIN,
            collect_caches: bool = False, positions=None,
            sync_grads: bool = False):
    """ids: (B, S) tokens. mem: frontend embeddings (vlm patches / audio
    frames). Returns (hidden, caches | None, aux_loss).

    sync_grads=True wraps parameters with nn.gradsync so weight cotangents
    cross the network as sharded bf16 reduce-scatters (see gradsync.py);
    layer params are wrapped *inside* the scan body.
    """
    from repro.nn.gradsync import sync_tree
    row_plan = _stack_rows(cfg, cfg.superblock) if sync_grads else None
    if sync_grads:
        top_plan = model_plan(cfg)
        params = dict(params)
        for key in ("embed", "final_norm", "mem_proj", "encoder",
                    "prefix"):
            if key in params:
                params[key] = sync_tree(params[key], top_plan[key],
                                        constrain)
    b, s = ids.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = embed(params["embed"], ids)
    x = constrain(x, ("batch", "seq", "embed"))
    if cfg.encoder is not None and mem is not None:
        mem = _run_encoder(params, cfg, mem, constrain)
    elif cfg.num_mem_tokens and mem is not None:
        mem = linear(params["mem_proj"], mem.astype(cfg.dtype))

    caches: dict = {}
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.prefix:
        caches["prefix"] = {}
        for i, row in enumerate(cfg.prefix):
            x, c, aux = _apply_row(cfg, row, params["prefix"][f"p{i}"], x,
                                   positions, mem, constrain)
            caches["prefix"][f"p{i}"] = c
            aux_total = aux_total + aux

    def body(carry, layer):
        x, aux_sum = carry
        if sync_grads:   # wrap layer slices so grads RS inside the scan
            layer = sync_tree(layer, row_plan, constrain)
        # residual stream is sequence-sharded at block boundaries (SP);
        # this is what the scan carry / remat residuals store.
        x = constrain(x, ("batch", "act_seq", "embed"))
        row_caches = {}
        for i, row in enumerate(cfg.superblock):
            x, c, aux = _apply_row(cfg, row, layer[f"r{i}"], x, positions,
                                   mem, constrain)
            row_caches[f"r{i}"] = c
            aux_sum = aux_sum + aux
        x = constrain(x, ("batch", "act_seq", "embed"))
        return (x, aux_sum), (row_caches if collect_caches else None)

    (x, aux_total), ys = jax.lax.scan(
        _remat(cfg, body), (x, aux_total), params["blocks"])
    if collect_caches:
        caches["blocks"] = ys
    x = _apply_norm(cfg, params["final_norm"], x)
    return x, (caches if collect_caches else None), aux_total


def loss_fn(params, cfg: LMConfig, batch, *, constrain=A.NO_CONSTRAIN,
            sync_grads: bool = False):
    """batch: {tokens (B,S), labels (B,S), [mask], [mem]} -> scalar loss."""
    sync = None
    if sync_grads:
        from repro.nn.gradsync import grad_sync
        sync = lambda w: grad_sync(w, ("embed", "vocab"), constrain)
    x, _, aux = forward(params, cfg, batch["tokens"], batch.get("mem"),
                        constrain=constrain, sync_grads=sync_grads)
    x = constrain(x, ("batch", None, "embed"))   # gather seq for the head
    loss, _ = chunked_softmax_xent(
        x, params["out"]["w"], batch["labels"],
        chunk=min(cfg.xent_chunk, x.shape[1]),
        label_mask=batch.get("mask"), table_grad_sync=sync)
    return loss + cfg.aux_loss_weight * aux


# ================================================================ decode ==
def _decode_row(cfg: LMConfig, row: Row, p, x, cache, pos, constrain):
    mixer, ffn = row
    new_cache = dict(cache)
    if mixer is not None:
        h = _apply_norm(cfg, p["norm1"], x)
        if mixer == "attn":
            y, k, v = A.attn_decode(p["mixer"], h, cache["k"], cache["v"],
                                    pos, cfg.attn, constrain)
            new_cache.update(k=k, v=v)
        elif mixer == "xattn":
            y = A.xattn_forward(p["mixer"], h, (cache["mk"], cache["mv"]),
                                cfg.attn, constrain)
        elif mixer == "mla":
            y, c = A.mla_decode(p["mixer"], h, cache["c"], pos, cfg.mla,
                                constrain)
            new_cache.update(c=c)
        elif mixer == "mamba":
            y, (conv, ssm) = M.mamba_decode(p["mixer"], h, cache["conv"],
                                            cache["ssm"], cfg.mamba,
                                            constrain)
            new_cache.update(conv=conv, ssm=ssm)
        elif mixer == "rwkv":
            y, (state, last) = R.time_mix_forward(
                p["mixer"], h, cfg.rwkv, state=cache["state"],
                x_last=cache["tm_last"], constrain=constrain)
            new_cache.update(state=state, tm_last=last)
        else:
            raise ValueError(mixer)
        x = x + y
    if ffn is not None:
        h = _apply_norm(cfg, p["norm2"], x)
        if ffn == "mlp":
            y = mlp(p["ffn"], h, cfg.activation)
        elif ffn == "moe":
            y, _ = MOE.moe_forward(p["ffn"], h, cfg.moe, constrain)
        elif ffn == "cmix":
            y, cm_last = R.channel_mix_forward(p["ffn"], h,
                                               cache.get("cm_last"))
            new_cache["cm_last"] = cm_last
        x = x + y
    return x, new_cache


def decode_step(params, cfg: LMConfig, caches, ids, pos, *,
                constrain=A.NO_CONSTRAIN):
    """One serving step: ids (B, 1) new tokens, pos scalar int32 position.

    Returns (logits (B, 1, vocab), updated caches). Cache buffers are
    donated by the serve jit wrapper.
    """
    b = ids.shape[0]
    x = embed(params["embed"], ids)
    x = constrain(x, ("batch", "seq", "embed"))
    new_caches: dict = {}
    if cfg.prefix:
        new_caches["prefix"] = {}
        for i, row in enumerate(cfg.prefix):
            x, c = _decode_row(cfg, row, params["prefix"][f"p{i}"], x,
                               caches["prefix"][f"p{i}"], pos, constrain)
            new_caches["prefix"][f"p{i}"] = c

    def body(x, layer_and_cache):
        layer, cache = layer_and_cache
        row_caches = {}
        for i, row in enumerate(cfg.superblock):
            x, c = _decode_row(cfg, row, layer[f"r{i}"], x, cache[f"r{i}"],
                               pos, constrain)
            row_caches[f"r{i}"] = c
        return x, row_caches

    x, blocks_cache = jax.lax.scan(body, x, (params["blocks"],
                                             caches["blocks"]))
    new_caches["blocks"] = blocks_cache
    x = _apply_norm(cfg, params["final_norm"], x)
    logits = linear(params["out"], x)
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits, new_caches


def prefill(params, cfg: LMConfig, ids, mem=None, *,
            constrain=A.NO_CONSTRAIN):
    """Run the full prompt, returning (last-token logits, caches)."""
    x, caches, _ = forward(params, cfg, ids, mem, constrain=constrain,
                           collect_caches=True)
    logits = linear(params["out"], x[:, -1:])
    return constrain(logits, ("batch", None, "vocab")), caches
