"""Single-pass partial aggregations (paper §V-B "Partial Aggregations").

GNNBuilder's FPGA kernels aggregate neighbor embeddings in O(1) space with
one pass over the (sorted) edge stream; variance/std use Welford's online
algorithm [37]. We implement the identical math twice:

* a *streaming* form (init / update / finalize) — consumed by the Pallas
  kernels (the padded-table ``gnn_aggregate`` and the packed-COO
  ``segment_aggregate``) and by the pure-scan reference, and
* a *segment* form over COO edge lists — the hot path for both padded
  graphs and the packed GraphBatch IR (DESIGN_BATCHING.md), dispatched
  through a backend switch: ``backend="xla"`` (default; jax.ops.segment_*
  lower to efficient sorted-segment reductions under pjit) or
  ``backend="pallas"`` (the fused ``kernels/segment_aggregate`` edge-block
  kernel, engaged on single-device serving via
  ``set_default_backend``/``--agg-backend``).

A third entry point, ``gather_aggregate``, fuses the *gather* stage into
the same dispatch: it takes the node-feature table plus the raw src/dst
edge-id streams (and an optional per-edge scale) instead of a
pre-gathered message tensor. Under ``backend="pallas"`` it lowers to
``kernels/fused_gather_aggregate`` and the (E, F) message tensor never
touches HBM — the paper's streamed gather->phi->aggregate pipeline;
under ``backend="xla"`` it materializes the messages with ``jnp.take``
and segment-reduces them (the safe pjit path, and the parity oracle).

Both entry points are *precision-polymorphic* (``precision=`` takes a
``quantization.LayerPrecision``): the node table / message tensor is
stored and streamed at the layer's compute width — bf16 tiles, or true
int8 tiles on the Pallas path (the per-tensor dequantization scale folds
into the kernels' existing per-edge scale path / finalize) — while
accumulation always runs in fp32 (exact int32-style sums for int8). The
XLA path mirrors the same numerics with fake-quant fp32 values, so the
two backends stay within fp32 tolerance of each other at every
precision (docs/KERNELS.md has the tolerance table).

Supported: sum, mean, min, max, var, std (matching the paper);
``gather_aggregate`` covers the sum/mean/min/max family that linear-phi
convs (GCN/SAGE/GIN) lower to.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

AGGREGATIONS = ("sum", "mean", "min", "max", "var", "std")

SEGMENT_BACKENDS = ("xla", "pallas")

# gather-stage kernel generations for ``gather_aggregate``'s Pallas path
# (dse.SPACE gather_mode): "dma" = the one-hot-free v2 kernel
# (scalar-prefetched ids + dynamic-slice gather), "onehot" = the legacy
# (N, EB) one-hot MXU contraction (docs/KERNELS.md)
GATHER_MODES = ("onehot", "dma")

# Process-wide defaults for ``segment_aggregate``'s backend=/tile
# arguments. "xla" everywhere a program may run under pjit; serving flips
# to "pallas" on single-device hosts (launch/serve.py --agg-backend).
# Tile sizes are the DSE knobs (dse.SPACE edge_block/node_block).
_DEFAULT_BACKEND = "xla"
_DEFAULT_EDGE_BLOCK = 128
_DEFAULT_NODE_BLOCK = 128
_DEFAULT_GATHER_MODE = "dma"
# None = auto: interpret the Pallas kernel everywhere except a real TPU
# backend (Mosaic compiles only there; interpret mode is the CPU/CI path)
_DEFAULT_INTERPRET: bool | None = None


def _resolve_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        interpret = _DEFAULT_INTERPRET
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return interpret


def set_default_backend(backend: str, edge_block: int | None = None,
                        node_block: int | None = None,
                        interpret: bool | None = None,
                        gather_mode: str | None = None) -> str:
    """Set the process default segment-aggregation backend (and
    optionally the Pallas tile sizes / interpret mode / gather kernel
    generation); returns the previous backend so callers can restore it.
    Trace-time effective: jitted programs bake in whichever defaults
    were set when first traced."""
    global _DEFAULT_BACKEND, _DEFAULT_EDGE_BLOCK, _DEFAULT_NODE_BLOCK, \
        _DEFAULT_INTERPRET, _DEFAULT_GATHER_MODE
    # validate everything before mutating anything: a rejected call must
    # leave the process defaults untouched (no half-applied state)
    if backend not in SEGMENT_BACKENDS:
        raise ValueError(backend)
    if gather_mode is not None and gather_mode not in GATHER_MODES:
        raise ValueError(gather_mode)
    prev = _DEFAULT_BACKEND
    _DEFAULT_BACKEND = backend
    if edge_block is not None:
        _DEFAULT_EDGE_BLOCK = int(edge_block)
    if node_block is not None:
        _DEFAULT_NODE_BLOCK = int(node_block)
    if interpret is not None:
        _DEFAULT_INTERPRET = bool(interpret)
    if gather_mode is not None:
        _DEFAULT_GATHER_MODE = gather_mode
    return prev


def default_backend() -> str:
    return _DEFAULT_BACKEND


@contextlib.contextmanager
def backend_scope(backend: str, edge_block: int | None = None,
                  node_block: int | None = None,
                  interpret: bool | None = None,
                  gather_mode: str | None = None):
    """Temporarily override the segment-aggregation defaults. Wrap the
    *tracing* of a jitted program (e.g. Project.gen_hw_model's infer fns)
    to bake a backend + tile choice into that program only."""
    global _DEFAULT_BACKEND, _DEFAULT_EDGE_BLOCK, _DEFAULT_NODE_BLOCK, \
        _DEFAULT_INTERPRET, _DEFAULT_GATHER_MODE
    prev = (_DEFAULT_BACKEND, _DEFAULT_EDGE_BLOCK, _DEFAULT_NODE_BLOCK,
            _DEFAULT_INTERPRET, _DEFAULT_GATHER_MODE)
    try:
        set_default_backend(backend, edge_block, node_block, interpret,
                            gather_mode)
        yield
    finally:
        (_DEFAULT_BACKEND, _DEFAULT_EDGE_BLOCK, _DEFAULT_NODE_BLOCK,
         _DEFAULT_INTERPRET, _DEFAULT_GATHER_MODE) = prev


# ------------------------------------------------------- streaming form --
def init_state(agg: str, dim: int, dtype=jnp.float32) -> dict:
    z = jnp.zeros((dim,), dtype)
    if agg == "sum" or agg == "mean":
        return {"acc": z, "count": jnp.zeros((), dtype)}
    if agg == "min":
        return {"acc": jnp.full((dim,), jnp.inf, dtype)}
    if agg == "max":
        return {"acc": jnp.full((dim,), -jnp.inf, dtype)}
    if agg in ("var", "std"):  # Welford: mean, M2, count
        return {"mean": z, "m2": z, "count": jnp.zeros((), dtype)}
    raise ValueError(agg)


def update(agg: str, state: dict, x) -> dict:
    """One neighbor embedding x: (dim,). O(1) space."""
    if agg in ("sum", "mean"):
        return {"acc": state["acc"] + x, "count": state["count"] + 1}
    if agg == "min":
        return {"acc": jnp.minimum(state["acc"], x)}
    if agg == "max":
        return {"acc": jnp.maximum(state["acc"], x)}
    if agg in ("var", "std"):
        c = state["count"] + 1
        delta = x - state["mean"]
        mean = state["mean"] + delta / c
        m2 = state["m2"] + delta * (x - mean)
        return {"mean": mean, "m2": m2, "count": c}
    raise ValueError(agg)


def finalize(agg: str, state: dict):
    if agg == "sum":
        return state["acc"]
    if agg == "mean":
        return state["acc"] / jnp.maximum(state["count"], 1.0)
    if agg in ("min", "max"):
        # isolated nodes: neutral element -> 0 (paper zero-fills)
        return jnp.where(jnp.isfinite(state["acc"]), state["acc"], 0.0)
    if agg in ("var", "std"):
        var = state["m2"] / jnp.maximum(state["count"], 1.0)
        var = jnp.maximum(var, 1e-12)   # clamp: sqrt'(0) = inf -> NaN grads
        return jnp.sqrt(var) if agg == "std" else var
    raise ValueError(agg)


def aggregate_stream(agg: str, xs, mask=None):
    """Reference streaming aggregation over xs: (n, dim) via lax.scan."""
    n, dim = xs.shape
    if mask is None:
        mask = jnp.ones((n,), bool)

    def step(state, inp):
        x, m = inp
        new = update(agg, state, x.astype(jnp.float32))
        state = jax.tree_util.tree_map(
            lambda a, b: jnp.where(m, b, a), state, new)
        return state, None

    state, _ = jax.lax.scan(step, init_state(agg, dim), (xs, mask))
    return finalize(agg, state)


def _active_precision(precision):
    """None for the fp32 fast path, the LayerPrecision otherwise."""
    if precision is None or precision.compute == "fp32":
        return None
    return precision


# --------------------------------------------------------- segment form --
def segment_aggregate(agg: str, messages, seg_ids, num_segments: int,
                      valid=None, *, backend: str | None = None,
                      edge_block: int | None = None,
                      node_block: int | None = None,
                      interpret: bool | None = None,
                      precision=None, gather_mode: str | None = None):
    """messages: (E, dim) -> (num_segments, dim). seg_ids: (E,) int32;
    padded edges carry seg_ids == num_segments (dropped).

    backend=None uses the process default (``set_default_backend``);
    "pallas" routes through the fused edge-block kernel with the given
    tile sizes (DSE knobs ``edge_block``/``node_block``), "xla" through
    jax.ops.segment_*. Both produce identical results to fp32 tolerance;
    the Pallas path is forward-only (no custom VJP yet).

    gather_mode=None uses the process default ("dma"): the one-hot-free
    v2 schedule — scalar-prefetched dst stream, double-buffered message
    DMA, whole-table VMEM accumulators, one sweep over the edge stream
    (this is the schedule PNA towers and var/std ride). "onehot" keeps
    the legacy (NB, EB) destination one-hot (GATHER_MODES; the DSE
    featurizes the choice).

    precision (a ``quantization.LayerPrecision``) sets the *storage*
    width of the message tensor: bf16 tiles, or — on the Pallas path —
    true int8 tiles quantized onto the layer's activation grid with the
    per-tensor dequant scale applied on the fp32 accumulator output
    (var scales by s^2, std by s, the linear family by s). The XLA path
    runs the same grids as fake-quant fp32. Accumulation is fp32 at
    every precision."""
    backend = backend or _DEFAULT_BACKEND
    if backend not in SEGMENT_BACKENDS:
        raise ValueError(backend)
    lp = _active_precision(precision)
    if lp is not None and lp.compute == "bf16":
        messages = messages.astype(jnp.bfloat16)
    if backend == "pallas":
        from repro.core import quantization as Q
        from repro.kernels.segment_aggregate.ops import (
            segment_aggregate as _pallas_segment_aggregate)
        dequant = None
        if lp is not None and lp.compute == "int8":
            messages = Q.quantize_int8(messages, lp.act_fpx)
            s = lp.act_fpx.resolution
            dequant = s * s if agg == "var" else s
        out = _pallas_segment_aggregate(
            messages, seg_ids, valid, num_segments=num_segments, agg=agg,
            edge_block=edge_block or _DEFAULT_EDGE_BLOCK,
            node_block=node_block or _DEFAULT_NODE_BLOCK,
            interpret=_resolve_interpret(interpret),
            gather_mode=gather_mode or _DEFAULT_GATHER_MODE)
        return out if dequant is None else out * dequant
    if lp is not None and lp.compute == "int8":
        from repro.core import quantization as Q
        messages = Q.quantize(messages, lp.act_fpx)   # fake-quant mirror
    if valid is not None:
        seg_ids = jnp.where(valid, seg_ids, num_segments)
    m = messages.astype(jnp.float32)
    ns = num_segments + 1           # +1 bucket swallows padding
    if agg == "sum":
        out = jax.ops.segment_sum(m, seg_ids, ns)
    elif agg == "mean":
        s = jax.ops.segment_sum(m, seg_ids, ns)
        c = jax.ops.segment_sum(jnp.ones_like(m[:, :1]), seg_ids, ns)
        out = s / jnp.maximum(c, 1.0)
    elif agg == "min":
        out = jax.ops.segment_min(m, seg_ids, ns)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    elif agg == "max":
        out = jax.ops.segment_max(m, seg_ids, ns)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    elif agg in ("var", "std"):
        # two-pass shifted form: E[(x - mu)^2] matches the Welford kernel
        # to fp32 tolerance (E[x^2] - E[x]^2 loses near-duplicate
        # segments to catastrophic cancellation)
        s = jax.ops.segment_sum(m, seg_ids, ns)
        c = jnp.maximum(jax.ops.segment_sum(
            jnp.ones_like(m[:, :1]), seg_ids, ns), 1.0)
        mu = s / c
        dev = m - jnp.take(mu, seg_ids, axis=0)
        var = jax.ops.segment_sum(jnp.square(dev), seg_ids, ns) / c
        var = jnp.maximum(var, 1e-12)
        out = jnp.sqrt(var) if agg == "std" else var
    else:
        raise ValueError(agg)
    return out[:num_segments]


def segment_softmax(logits, seg_ids, num_segments: int, valid=None, *,
                    backend: str | None = None,
                    edge_block: int | None = None,
                    interpret: bool | None = None):
    """Per-edge softmax weights normalized within each destination
    segment — the attention-conv reduction (GAT). logits: (E,) ->
    (E,) float32; seg_ids: (E,) int32 with padding marked by -1, any id
    >= num_segments, or ``valid == False``.

    Numerically stable at any logit magnitude: both backends subtract
    the per-segment max before exponentiating (the Pallas path is the
    online-softmax machine of ``kernels/segment_softmax``; the XLA path
    is segment_max + shifted exp + segment_sum), so +-1e4 logits never
    overflow. A -inf logit on a valid edge is a masked attention slot:
    it contributes 0 to the denominator and gets weight 0; an all-masked
    or empty segment yields all-zero weights — never NaN/Inf.

    Attention weights are *not* precision-polymorphic: the logit/softmax
    math always runs fp32 regardless of the layer's PrecisionPolicy
    (the documented int8 exclusion — only the projection and the
    aggregate message stream quantize; docs/KERNELS.md)."""
    backend = backend or _DEFAULT_BACKEND
    if backend not in SEGMENT_BACKENDS:
        raise ValueError(backend)
    if backend == "pallas":
        from repro.kernels.segment_softmax.ops import (
            segment_softmax as _pallas_segment_softmax)
        return _pallas_segment_softmax(
            logits, seg_ids, valid, num_segments=num_segments,
            edge_block=edge_block or _DEFAULT_EDGE_BLOCK,
            interpret=_resolve_interpret(interpret))
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    ok = (seg_ids >= 0) & (seg_ids < num_segments)
    if valid is not None:
        ok = ok & valid
    seg = jnp.where(ok, seg_ids, num_segments)
    ns = num_segments + 1           # +1 bucket swallows padding
    z = jnp.asarray(logits, jnp.float32)
    m = jax.ops.segment_max(z, seg, ns)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    # mask before the exp: a padding logit can exceed its (overflow)
    # bucket statistics and overflow to +inf on lanes where() discards
    p = jnp.where(ok, jnp.exp(jnp.where(ok, z, -jnp.inf)
                              - jnp.take(m_safe, seg)), 0.0)
    denom = jax.ops.segment_sum(p, seg, ns)
    return p / jnp.maximum(jnp.take(denom, seg), 1e-30)


GATHER_AGGREGATIONS = ("sum", "mean", "min", "max")


def gather_aggregate(agg: str, x, src, dst, num_segments: int, valid=None,
                     scale=None, *, backend: str | None = None,
                     edge_block: int | None = None,
                     node_block: int | None = None,
                     interpret: bool | None = None,
                     precision=None, gather_mode: str | None = None):
    """Fused gather -> phi -> aggregate over packed COO id streams.

    x: (N, F) node features; src/dst: (E,) int32 endpoint ids (padding:
    -1, out-of-range, or ``valid == False``); scale: optional (E,)
    per-edge message scale applied before aggregation (the GCN symmetric
    norm). Returns (num_segments, F) float32.

    backend=None uses the process default. "pallas" routes through the
    fused edge-block kernel for sum/mean/min/max — the (E, F) message
    tensor is never materialized; var/std fall back to the materialized
    gather + the Pallas segment kernel. "xla" always materializes
    ``jnp.take(x, src)`` and segment-reduces it — the materialized
    baseline the fused kernel is numerics-pinned against.

    gather_mode=None uses the process default ("dma"): the one-hot-free
    v2 kernel — scalar-prefetched id streams, per-edge dynamic-slice
    gather, double-buffered scale copies. "onehot" keeps the legacy
    (N, EB) one-hot MXU contraction (GATHER_MODES; the DSE featurizes
    the choice).

    precision (a ``quantization.LayerPrecision``) sets the storage width
    of the node table: bf16 tiles, or — on the fused Pallas path — true
    int8 tiles whose per-tensor dequant scale is *folded into the
    existing per-edge scale stream* (phi costs nothing extra; the fold is
    exact for the whole sum/mean/min/max family since the scale is a
    positive per-tensor constant). The XLA path mirrors the same grid as
    fake-quant fp32; accumulation is fp32 everywhere."""
    backend = backend or _DEFAULT_BACKEND
    if backend not in SEGMENT_BACKENDS:
        raise ValueError(backend)
    lp = _active_precision(precision)
    if lp is not None and lp.compute == "bf16":
        x = x.astype(jnp.bfloat16)
    if backend == "pallas" and agg in GATHER_AGGREGATIONS:
        from repro.kernels.fused_gather_aggregate.ops import (
            fused_gather_aggregate as _pallas_gather_aggregate)
        if lp is not None and lp.compute == "int8":
            from repro.core import quantization as Q
            s = lp.act_fpx.resolution
            x = Q.quantize_int8(x, lp.act_fpx)
            scale = jnp.full(jnp.asarray(src).shape, s, jnp.float32) \
                if scale is None else scale.astype(jnp.float32) * s
        return _pallas_gather_aggregate(
            x, src, dst, valid, scale, num_segments=num_segments, agg=agg,
            edge_block=edge_block or _DEFAULT_EDGE_BLOCK,
            node_block=node_block or _DEFAULT_NODE_BLOCK,
            interpret=_resolve_interpret(interpret),
            gather_mode=gather_mode or _DEFAULT_GATHER_MODE)
    if lp is not None and lp.compute == "int8":
        from repro.core import quantization as Q
        x = Q.quantize(x, lp.act_fpx)                 # fake-quant mirror
    # materialized path: gather the (E, F) message tensor, then reduce.
    # Out-of-range ids on *either* stream are padding (same contract as
    # the fused kernel): clamp before the take so no fill-value NaNs can
    # leak, and drop the edge via the validity mask. The gathered
    # messages keep x's storage dtype until the scale/accumulate stage.
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    msg = jnp.take(x, jnp.clip(src, 0, x.shape[0] - 1), axis=0)
    if scale is not None:
        msg = msg.astype(jnp.float32) * scale[:, None]
    ok = (src >= 0) & (src < x.shape[0]) \
        & (dst >= 0) & (dst < num_segments)
    if valid is not None:
        ok = ok & valid
    # the fused family quantizes the *table* (above) so the pallas and
    # XLA traces see identical messages; the non-fused aggregations
    # (var/std) share this materialized path on both backends, so the
    # precision forwards to the segment stage and the message tensor
    # itself streams at storage width through the segment kernel
    inner_lp = lp if agg not in GATHER_AGGREGATIONS else None
    return segment_aggregate(agg, msg, dst, num_segments, ok,
                             backend=backend, edge_block=edge_block,
                             node_block=node_block, interpret=interpret,
                             precision=inner_lp, gather_mode=gather_mode)


def segment_counts(seg_ids, num_segments: int, valid=None):
    """Per-segment element counts: (E,) int ids -> (num_segments,) float.

    With packed GraphBatch buffers this yields per-graph node or edge
    counts (pass node_graph_id / edge_graph_id); padding slots carry
    seg_ids == num_segments and fall into the dropped overflow bucket.
    """
    seg_ids = jnp.asarray(seg_ids)
    if valid is not None:
        seg_ids = jnp.where(valid, seg_ids, num_segments)
    ones = jnp.ones(seg_ids.shape, jnp.float32)
    return jax.ops.segment_sum(ones, seg_ids, num_segments + 1)[
        :num_segments]


def degrees(edge_index, num_nodes: int, valid=None):
    """(in_degree, out_degree) from padded COO (E, 2) with -1 padding."""
    src, dst = edge_index[:, 0], edge_index[:, 1]
    if valid is None:
        valid = src >= 0
    ones = valid.astype(jnp.float32)
    indeg = jax.ops.segment_sum(
        ones, jnp.where(valid, dst, num_nodes), num_nodes + 1)[:num_nodes]
    outdeg = jax.ops.segment_sum(
        ones, jnp.where(valid, src, num_nodes), num_nodes + 1)[:num_nodes]
    return indeg, outdeg
