"""Design space exploration (paper §VII-C, §VIII-A Listing 2).

The design space is the paper's Listing-2 grid: conv type x hidden dims x
layers x skip x MLP dims x parallelism factors. ``build_database``
"synthesizes" sampled designs (XLA compile + report — the Vitis analogue),
``fit_models`` trains the direct-fit RF latency/memory models, and
``explore`` brute-forces the space through the millisecond-scale models
under a resource constraint — the paper's seconds-vs-days DSE claim.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import logging
import time

import numpy as np

from repro.core import convs as Cv
from repro.core import gnn_model as G
from repro.core import perf_model as PM
from repro.core import quantization as Q
from repro.core.project import Project, TPUTarget
from repro.data.pipeline import GraphDataConfig, size_budget

log_ = logging.getLogger(__name__)

# Listing 2 (paper) design space, extended with the packed-batch budget
# axis (batch_graphs sizes the GraphBatch node/edge buffers — the on-chip
# working-set knob the fitted models learn throughput against) and the
# segment-aggregation kernel tile sizes (edge_block/node_block — the TPU
# analogue of the paper's parallelization factors, autotuned the same
# way: sampled, synthesized, and predicted by the fitted models).
SPACE = {
    # conv axis: derived from the conv registry (convs.CONV_REGISTRY) —
    # registering a conv with dse=True adds it here and to the
    # perf-model conv one-hots without touching this module
    "conv": None,           # filled by _rebuild_conv_axis below
    "gnn_hidden_dim": [64, 128, 256],
    "gnn_out_dim": [64, 128, 256],
    "gnn_layers": [1, 2, 3, 4],
    "skip": [True, False],
    "mlp_hidden_dim": [64, 128, 256],
    "mlp_layers": [1, 2, 3, 4],
    "gnn_p_in": [1],
    "gnn_p_hidden": [2, 4, 8],
    "gnn_p_out": [2, 4, 8],
    "mlp_p_in": [2, 4, 8],
    "mlp_p_hidden": [2, 4, 8],
    "mlp_p_out": [1],
    "batch_graphs": [8, 16, 32, 64],
    "edge_block": [64, 128, 256],
    "node_block": [32, 64, 128],
    # transform/aggregate ordering for the linear convs
    # (convs.resolve_dataflow): "auto" defers to the closed-form cost
    # model, the explicit values pin one ordering for the whole stack
    "dataflow": ["auto", "aggregate_first", "transform_first"],
    # datapath precision (quantization.PRECISIONS): the per-model knob of
    # the PrecisionPolicy subsystem — storage/streaming width of the conv
    # datapath, priced by the fitted models through the byte-width
    # features (perf_model precision_* / compute_bytes)
    "precision": list(Q.PRECISIONS),
    # data-parallel device shards (graph-level partitioning over a
    # ("data",) mesh): each device runs the per-shard packed program on
    # its own GraphBatch; the budgets above stay per-shard, throughput
    # scales near-linearly (perf_model shards_* one-hot)
    "num_shards": [1, 2, 4, 8],
    # gather kernel generation (aggregations.GATHER_MODES): "dma" is the
    # one-hot-free v2 kernel, "onehot" the legacy dense contraction kept
    # searchable so the fitted models can price the difference
    "gather_mode": ["onehot", "dma"],
    # layers fused per launch by the VMEM-residency kernel (>1 engages
    # apply_packed_resident when convs.residency_plan allows it)
    "fusion_depth": [1, 2, 4],
    # intra-graph edge-cut partitioning (pipeline.partition_graph): how
    # many devices one oversize graph is split across, halo rows
    # exchanged between layers. Priced by the comm-cost term
    # (convs.halo_comm_bytes); orthogonal to num_shards, which
    # replicates whole graphs
    "partition": [1, 2, 4, 8],
}


def _rebuild_conv_axis():
    SPACE["conv"] = [n for n in Cv.CONV_TYPES if Cv.conv_spec(n).dse]


_rebuild_conv_axis()
Cv.on_registry_change(_rebuild_conv_axis)


def space_size() -> int:
    n = 1
    for v in SPACE.values():
        n *= len(v)
    return n


def sample_design(rng, *, in_dim: int = 9, edge_dim: int = 3,
                  avg_nodes: float = 18, avg_edges: float = 38,
                  avg_degree: float = 2.1, out_dim: int = 1) -> dict:
    d = {k: v[rng.integers(0, len(v))] for k, v in SPACE.items()}
    d.update(in_dim=in_dim, edge_dim=edge_dim, avg_nodes=avg_nodes,
             avg_edges=avg_edges, avg_degree=avg_degree, out_dim=out_dim,
             fpx_bits=8 * Q.BYTE_WIDTHS[d["precision"]])
    # budgets are per shard: a sharded design replicates the same
    # buffers on every device
    d["node_budget"] = size_budget(d["batch_graphs"], avg_nodes)
    d["edge_budget"] = size_budget(d["batch_graphs"], avg_edges)
    return d


def design_name(d: dict) -> str:
    """Stable build-dir name: sha1 of the sorted design items, so cached
    reports are reproducible across processes (PYTHONHASHSEED-proof)."""
    digest = hashlib.sha1(
        repr(sorted(d.items())).encode("utf-8")).hexdigest()
    return f"dse_{digest[:12]}"


def design_to_config(d: dict) -> G.GNNModelConfig:
    pooled = d["gnn_out_dim"] * 3
    return G.GNNModelConfig(
        graph_input_feature_dim=d["in_dim"],
        graph_input_edge_dim=d["edge_dim"],
        gnn_hidden_dim=d["gnn_hidden_dim"],
        gnn_num_layers=d["gnn_layers"],
        gnn_output_dim=d["gnn_out_dim"],
        gnn_conv=d["conv"],
        gnn_skip_connection=d["skip"],
        global_pooling=("add", "mean", "max"),
        mlp_head=G.MLPConfig(in_dim=pooled, out_dim=d["out_dim"],
                             hidden_dim=d["mlp_hidden_dim"],
                             hidden_layers=d["mlp_layers"],
                             p_in=d["mlp_p_in"],
                             p_hidden=d["mlp_p_hidden"],
                             p_out=d["mlp_p_out"]),
        gnn_p_in=d["gnn_p_in"], gnn_p_hidden=d["gnn_p_hidden"],
        gnn_p_out=d["gnn_p_out"],
        pna_delta=float(np.log(d["avg_degree"] + 1.0)),
        gnn_dataflow=d.get("dataflow", "auto"),
        avg_degree=float(d["avg_degree"]),
        gnn_precision=d.get("precision", "fp32"))


def synthesize_design(d: dict, build_dir: str, max_nodes: int = 600,
                      max_edges: int = 600, run_testbench: bool = False,
                      tb_graphs: int = 12) -> dict:
    """One 'synthesis run': compile + report (+ optional measured runtime)."""
    cfg = design_to_config(d)
    proj = Project(
        design_name(d), cfg, "dse", build_dir,
        dataset_cfg=GraphDataConfig(node_feat_dim=d["in_dim"],
                                    edge_feat_dim=d["edge_dim"],
                                    max_nodes=max_nodes,
                                    max_edges=max_edges),
        max_nodes=max_nodes, max_edges=max_edges,
        num_nodes_guess=d["avg_nodes"], num_edges_guess=d["avg_edges"],
        degree_guess=d["avg_degree"],
        batch_graphs=d.get("batch_graphs", 32),
        node_budget=d.get("node_budget"), edge_budget=d.get("edge_budget"),
        edge_block=d.get("edge_block", 128),
        node_block=d.get("node_block", 128),
        num_shards=d.get("num_shards", 1),
        gather_mode=d.get("gather_mode", "dma"),
        fusion_depth=d.get("fusion_depth", 1),
        partition=d.get("partition", 1))
    proj.gen_hw_model()
    report = proj.run_synthesis()
    out = dict(d)
    out["latency_s"] = report["latency_s"]
    out["hbm_bytes"] = report["hbm_total_bytes"]
    out["flops"] = report["flops"]
    out["compile_s"] = report["compile_s"]
    # the fitted throughput target is the whole design's graphs/s: the
    # sharded wave rate for num_shards > 1 (the per-shard program is
    # compiled once; the sharded figure is the analytic scaling model)
    out["graphs_per_s"] = report["packed"]["sharded"]["graphs_per_s"]
    out["graphs_per_s_single"] = report["packed"]["graphs_per_s"]
    out["packed_latency_s"] = report["packed"]["latency_s"]
    if run_testbench:
        proj.init_params()
        proj.gen_testbench(tb_graphs)
        tb = proj.build_and_run_testbench()
        out["measured_ms"] = tb["mean_runtime_ms"]
    return out


def build_database(n: int, build_dir: str, seed: int = 0,
                   run_testbench: bool = False, log=print) -> list:
    rng = np.random.default_rng(seed)
    db = []
    for i in range(n):
        d = sample_design(rng)
        t0 = time.time()
        rec = synthesize_design(d, build_dir, run_testbench=run_testbench)
        db.append(rec)
        if log and (i + 1) % 20 == 0:
            log(f"  synthesized {i + 1}/{n} designs "
                f"({time.time() - t0:.1f}s/design)")
    return db


@dataclasses.dataclass
class FittedModels:
    latency: PM.RandomForestRegressor
    memory: PM.RandomForestRegressor
    throughput: PM.RandomForestRegressor | None = None

    def predict(self, designs: list) -> tuple:
        x = np.stack([PM.features(d) for d in designs])
        return self.latency.predict(x), self.memory.predict(x)

    def predict_throughput(self, designs: list):
        if self.throughput is None:
            return None
        x = np.stack([PM.features(d) for d in designs])
        return self.throughput.predict(x)


def fit_models(db: list, latency_key: str = "latency_s",
               memory_key: str = "hbm_bytes",
               throughput_key: str = "graphs_per_s") -> FittedModels:
    x = np.stack([PM.features(d) for d in db])
    lat = PM.RandomForestRegressor().fit(
        x, np.array([d[latency_key] for d in db]))
    mem = PM.RandomForestRegressor().fit(
        x, np.array([d[memory_key] for d in db]))
    thr = None
    if all(throughput_key in d for d in db):
        # batch-budget features let the forest learn packed throughput
        thr = PM.RandomForestRegressor().fit(
            x, np.array([d[throughput_key] for d in db]))
    return FittedModels(lat, mem, thr)


# SLO-aware exploration defaults (objective="p99_latency"): the offered
# load the design must sustain, the launch-policy deadline, the scripted
# trace length, and how many best-predicted-latency candidates get the
# (pure-virtual-time, jax-free) traffic simulation.
DEFAULT_SLO = {
    "load_graphs_per_s": 2048.0,
    "deadline_s": 0.02,
    "n_requests": 192,
    "top_k": 24,
    "trace_seed": 0,
    "max_queue_depth": 4096,
}


def simulate_traffic(d: dict, service_s: float, trace,
                     deadline_s: float = 0.02,
                     max_queue_depth: int = 4096) -> dict:
    """Serve ``trace`` (an open-loop arrival process) through the
    continuous-batching scheduler with design ``d``'s packed budgets and
    a constant per-launch service time (the packed program is
    fixed-shape, so a launch costs the same however full it is).
    Pure virtual time — milliseconds per candidate, no devices touched.
    Returns the scheduler's summary (p50/p99 latency, fill, rejections).
    """
    from repro.runtime import scheduler as S
    cfg = S.SchedulerConfig(
        node_budget=d["node_budget"], edge_budget=d["edge_budget"],
        max_graphs=d["batch_graphs"], max_queue_depth=max_queue_depth,
        default_tier=S.SLOTier("standard", deadline_s, 1))
    sched = S.ContinuousScheduler(
        cfg, S.SimExecutor(S.constant_service(service_s)))
    S.run_trace(sched, trace)
    return sched.summary()


def explore(models: FittedModels, n_candidates: int = 4096, seed: int = 1,
            memory_budget: float = TPUTarget().hbm_bytes,
            base: dict | None = None, objective: str = "latency",
            slo: dict | None = None) -> dict:
    """Random-sample the space, predict in milliseconds, return the best
    design under the memory constraint (paper DSE loop).

    ``objective="latency"`` (default) minimizes predicted batch latency —
    the paper's offline objective. ``objective="p99_latency"`` minimizes
    the *p99 request latency under traffic*: the ``slo["top_k"]``
    best-predicted candidates that fit the memory budget are each
    simulated serving an open-loop Poisson arrival trace at
    ``slo["load_graphs_per_s"]`` through the continuous-batching
    scheduler (``simulate_traffic``; per-launch service time is
    ``batch_graphs / predicted_graphs_per_s``), and the winner is the
    lowest simulated p99 — so budget/deadline configs are chosen
    against the traffic they must carry, not raw throughput
    (docs/DSE.md).

    Fails soft: when no candidate fits the budget, the best-latency
    infeasible design is returned flagged ``feasible: False`` with its
    violation margin, instead of raising.
    """
    if objective not in ("latency", "p99_latency"):
        raise ValueError(f"unknown objective {objective!r}")
    rng = np.random.default_rng(seed)
    cands = []
    for _ in range(n_candidates):
        d = sample_design(rng, **(base or {}))
        cands.append(d)
    t0 = time.time()
    x = np.stack([PM.features(d) for d in cands])   # featurize once
    lat = models.latency.predict(x)
    mem = models.memory.predict(x)
    thr = models.throughput.predict(x) if models.throughput is not None \
        else None
    elapsed = time.time() - t0

    def result(i, feasible):
        best = dict(cands[i])
        best["pred_latency_s"] = float(lat[i])
        best["pred_hbm_bytes"] = float(mem[i])
        if thr is not None:
            best["pred_graphs_per_s"] = float(thr[i])
        best["dse_seconds"] = elapsed
        best["ms_per_eval"] = elapsed / n_candidates * 1e3
        best["feasible"] = feasible
        return best

    order = np.argsort(lat)
    if objective == "p99_latency":
        return _explore_slo(cands, lat, mem, thr, order, memory_budget,
                            dict(DEFAULT_SLO, **(slo or {})), result)
    for i in order:
        if mem[i] <= memory_budget:
            return result(i, True)
    i = order[0]
    violation = float(mem[i] - memory_budget)
    log_.warning(
        "no design fits the memory budget (%.3g B); returning best "
        "infeasible design, violation margin %.3g B", memory_budget,
        violation)
    best = result(i, False)
    best["memory_violation_bytes"] = violation
    return best


def _explore_slo(cands, lat, mem, thr, order, memory_budget, slo,
                 result) -> dict:
    """The p99-under-load tail of ``explore``: simulate the top-k
    feasible candidates through the scheduler and rank by p99."""
    from repro.runtime import scheduler as S
    feasible_idx = [i for i in order if mem[i] <= memory_budget]
    feasible = bool(feasible_idx)
    if not feasible:
        log_.warning(
            "no design fits the memory budget (%.3g B); simulating the "
            "best infeasible candidates instead", memory_budget)
    pool = (feasible_idx or list(order))[:int(slo["top_k"])]
    ds_cfg = GraphDataConfig(num_graphs=int(slo["n_requests"]),
                             seed=int(slo["trace_seed"]))
    trace = S.poisson_trace(int(slo["n_requests"]),
                            float(slo["load_graphs_per_s"]), ds_cfg,
                            seed=int(slo["trace_seed"]))
    t0 = time.time()
    best_i, best_p99, best_summary = None, float("inf"), None
    for i in pool:
        d = cands[i]
        if thr is not None and thr[i] > 0:
            service_s = d["batch_graphs"] / float(thr[i])
        else:
            service_s = float(lat[i])
        summary = simulate_traffic(
            d, service_s, trace, deadline_s=float(slo["deadline_s"]),
            max_queue_depth=int(slo["max_queue_depth"]))
        # a design that sheds load cannot win on the latency of the
        # requests it deigned to answer: rejections disqualify first.
        # p99 is None when the design served nothing at all — rank that
        # as infinitely bad rather than letting the tuple compare fail
        p99 = summary["p99_latency_s"]
        key = (summary["rejected_queue_full"],
               float("inf") if p99 is None else p99)
        if best_summary is None or key < (
                best_summary["rejected_queue_full"], best_p99):
            best_i, best_p99, best_summary = i, key[1], summary
    best = result(best_i, feasible)
    if not feasible:
        best["memory_violation_bytes"] = float(mem[best_i] - memory_budget)
    best["objective"] = "p99_latency"
    best["pred_p99_latency_s"] = float(best_p99)
    p50 = best_summary["p50_latency_s"]
    best["pred_p50_latency_s"] = float("inf") if p50 is None else float(p50)
    best["pred_batch_fill"] = float(best_summary["mean_batch_fill"])
    best["pred_rejected"] = int(best_summary["rejected_queue_full"])
    best["slo"] = dict(slo)
    best["slo_sim_seconds"] = time.time() - t0
    return best
