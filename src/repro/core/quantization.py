"""Fixed-point quantization emulation (paper's FPX(W, I) = ap_fixed<W,I>).

``FPX(32, 16)`` means 32 total bits with 16 integer bits (signed), i.e.
16 fractional bits: values quantize to round(x * 2^F) / 2^F clipped to
[-2^(I-1), 2^(I-1) - 2^-F]. The testbench casts weights + activations
through this grid to reproduce the paper's "true quantization simulation";
a per-layer hook inserts activation quantization after every conv/linear.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FPX:
    w: int = 32          # total bits
    i: int = 16          # integer bits (including sign)

    @property
    def frac_bits(self) -> int:
        return self.w - self.i

    @property
    def min_val(self) -> float:
        return -(2.0 ** (self.i - 1))

    @property
    def max_val(self) -> float:
        return 2.0 ** (self.i - 1) - 2.0 ** (-self.frac_bits)

    @property
    def resolution(self) -> float:
        return 2.0 ** (-self.frac_bits)

    def __str__(self):
        return f"fpx<{self.w},{self.i}>"


def quantize(x, fpx: FPX):
    """Round-to-nearest onto the fixed-point grid, saturating."""
    scale = 2.0 ** fpx.frac_bits
    q = jnp.round(x.astype(jnp.float32) * scale) / scale
    return jnp.clip(q, fpx.min_val, fpx.max_val)


def quantize_tree(tree, fpx: FPX):
    return jax.tree_util.tree_map(
        lambda a: quantize(a, fpx) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


def quant_error(x, fpx: FPX):
    return jnp.abs(quantize(x, fpx) - x.astype(jnp.float32))
