"""Fixed-point quantization + the per-layer PrecisionPolicy subsystem.

Two layers of machinery live here:

* **FPX** — the paper's ``ap_fixed<W,I>`` grid emulation. ``FPX(32, 16)``
  means 32 total bits with 16 integer bits (signed), i.e. 16 fractional
  bits: values quantize to round(x * 2^F) / 2^F clipped to
  [-2^(I-1), 2^(I-1) - 2^-F]. ``quantize`` is the fake-quant form (fp32
  values on the grid); ``quantize_int8`` / ``dequantize_int8`` are the
  *real* integer representation of an 8-bit grid — for power-of-two
  scales the two are exactly equivalent
  (``dequantize_int8(quantize_int8(x, fpx), fpx) == quantize(x, fpx)``),
  which is what lets the Pallas kernels move int8 tiles while the XLA
  baseline runs on fake-quant fp32 with identical numerics.

* **PrecisionPolicy** — the per-layer precision spec threaded end-to-end
  (kernels -> convs -> gnn_model -> Project -> DSE -> serve). Each layer
  carries a ``LayerPrecision`` with a compute dtype (fp32 | bf16 | int8),
  an accumulator dtype (always fp32/int32 — low-precision *storage and
  streaming*, full-precision accumulation), and the int8 grids for
  activations/weights. ``resolve_policy`` builds the policy once per
  model; ``calibrate_policy`` fits the int8 grids by max-abs on a
  calibration batch (``gnn_model.activation_ranges``).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FPX:
    w: int = 32          # total bits
    i: int = 16          # integer bits (including sign)

    def __post_init__(self):
        # FPX(4, 8) would silently yield negative frac bits and a
        # nonsense grid — reject malformed formats loudly instead.
        if self.w <= 0:
            raise ValueError(f"FPX total bits must be positive, got w="
                             f"{self.w}")
        if self.i < 1:
            raise ValueError(f"FPX needs at least the sign bit as an "
                             f"integer bit, got i={self.i}")
        if self.i > self.w:
            raise ValueError(f"FPX integer bits cannot exceed total bits: "
                             f"i={self.i} > w={self.w}")

    @property
    def frac_bits(self) -> int:
        return self.w - self.i

    @property
    def min_val(self) -> float:
        return -(2.0 ** (self.i - 1))

    @property
    def max_val(self) -> float:
        return 2.0 ** (self.i - 1) - 2.0 ** (-self.frac_bits)

    @property
    def resolution(self) -> float:
        return 2.0 ** (-self.frac_bits)

    def __str__(self):
        return f"fpx<{self.w},{self.i}>"


def fpx_for_max_abs(max_abs: float, w: int = 8) -> FPX:
    """Max-abs calibration: the narrowest ``FPX(w, i)`` grid whose range
    covers ``max_abs`` (the scale-fitting rule of the int8 path). The
    exact maximum may still clip by one resolution step — standard
    symmetric-quantization behavior."""
    if not math.isfinite(max_abs) or max_abs <= 0.0:
        return FPX(w, 1)
    i = int(math.ceil(math.log2(max_abs))) + 1
    return FPX(w, min(max(i, 1), w))


@jax.custom_jvp
def _ste(xf, q):
    """Straight-through estimator: forward the (bit-exact) grid value,
    backward the identity tangent of the pre-quantization input."""
    return q


@_ste.defjvp
def _ste_jvp(primals, tangents):
    _, q = primals
    dx, _ = tangents
    return q, dx


def quantize(x, fpx: FPX):
    """Round-to-nearest onto the fixed-point grid, saturating (fake-quant:
    fp32 values that lie exactly on the grid).

    Differentiable via the straight-through estimator: the grid is
    piecewise-constant (zero gradient almost everywhere), so training
    through a fake-quant datapath — the legacy testbench hook or a
    DSE-sampled int8 PrecisionPolicy — would otherwise silently receive
    all-zero weight/activation gradients."""
    xf = x.astype(jnp.float32)
    scale = 2.0 ** fpx.frac_bits
    q = jnp.clip(jnp.round(xf * scale) / scale, fpx.min_val, fpx.max_val)
    return _ste(xf, q)


def quantize_int8(x, fpx: FPX):
    """Real integer representation of an 8-bit fixed-point grid:
    ``q = clip(round(x / resolution))`` as int8. Exactly equivalent to
    the fake-quant form: ``dequantize_int8(quantize_int8(x, fpx), fpx)
    == quantize(x, fpx)`` (power-of-two scales are exact in fp32)."""
    assert fpx.w == 8, f"int8 grid needs w=8, got {fpx}"
    q = jnp.round(x.astype(jnp.float32) / fpx.resolution)
    return jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def dequantize_int8(q, fpx: FPX):
    return q.astype(jnp.float32) * fpx.resolution


def quantize_tree(tree, fpx: FPX):
    return jax.tree_util.tree_map(
        lambda a: quantize(a, fpx) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


def quant_error(x, fpx: FPX):
    return jnp.abs(quantize(x, fpx) - x.astype(jnp.float32))


def error_stats(x, ref) -> dict:
    """Mean/max absolute error + SQNR in dB of ``x`` against ``ref``.
    SQNR = 10 log10(signal power / error power); inf when exact."""
    x = jnp.asarray(x, jnp.float32)
    ref = jnp.asarray(ref, jnp.float32)
    err = x - ref
    sig_p = float(jnp.mean(jnp.square(ref)))
    err_p = float(jnp.mean(jnp.square(err)))
    sqnr = float("inf") if err_p == 0.0 \
        else 10.0 * math.log10(max(sig_p, 1e-30) / err_p)
    return {"mean_abs": float(jnp.mean(jnp.abs(err))),
            "max_abs": float(jnp.max(jnp.abs(err))) if err.size else 0.0,
            "sqnr_db": sqnr}


def quant_error_stats(x, fpx: FPX) -> dict:
    """Quantization-error summary of casting ``x`` through ``fpx``:
    mean/max absolute error + SQNR-dB — the reduced form Project's
    testbench reports (callers no longer re-reduce ``quant_error``)."""
    return error_stats(quantize(jnp.asarray(x), fpx), x)


# --------------------------------------------------- precision policy ----
PRECISIONS = ("fp32", "bf16", "int8")
COMPUTE_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16,
                  "int8": jnp.int8}
BYTE_WIDTHS = {"fp32": 4, "bf16": 2, "int8": 1}
ACCUM_DTYPES = {"fp32": "fp32", "bf16": "fp32", "int8": "int32"}


@dataclasses.dataclass(frozen=True)
class LayerPrecision:
    """Precision of one layer's datapath: values (node/message tiles,
    weights) are stored and streamed at ``compute`` width; accumulation
    always runs at full precision (``accum``: fp32 for the float
    formats, int32-exact for int8 — integer sums are exactly
    representable in the fp32 emulation up to 2^24)."""
    compute: str = "fp32"              # fp32 | bf16 | int8
    act_fpx: FPX = FPX(8, 3)           # int8: activation/message grid
    weight_fpx: FPX = FPX(8, 2)        # int8: weight grid
    # int8: separate per-tensor grid for the tensor *entering* the layer
    # when its range differs from the internal activations (the MLP
    # head's pooled input vs its hidden activations); None = act_fpx
    in_fpx: FPX | None = None

    def __post_init__(self):
        if self.compute not in PRECISIONS:
            raise ValueError(f"unknown compute dtype {self.compute!r}; "
                             f"expected one of {PRECISIONS}")

    @property
    def accum(self) -> str:
        return ACCUM_DTYPES[self.compute]

    @property
    def bytes_per_value(self) -> int:
        return BYTE_WIDTHS[self.compute]

    @property
    def dtype(self):
        return COMPUTE_DTYPES[self.compute]

    def cast_activation(self, x):
        """Activations entering this layer's datapath: bf16 really
        casts; int8 fake-quants onto the input grid (the kernels'
        dispatch converts to true int8 tiles); fp32 is identity."""
        if self.compute == "bf16":
            return x.astype(jnp.bfloat16)
        if self.compute == "int8":
            return quantize(x, self.in_fpx or self.act_fpx)
        return x

    def cast_params(self, tree):
        """Weights of this layer: bf16 casts, int8 fake-quants onto the
        weight grid (per-tensor scale), fp32 is identity."""
        if self.compute == "bf16":
            return jax.tree_util.tree_map(
                lambda a: a.astype(jnp.bfloat16)
                if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)
        if self.compute == "int8":
            return quantize_tree(tree, self.weight_fpx)
        return tree


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-layer precision spec, resolved once per model: one
    ``LayerPrecision`` per conv layer plus one for the MLP head.
    ``calibrated`` marks int8 grids fitted by max-abs calibration
    (``calibrate_policy``) rather than the conservative defaults."""
    name: str = "fp32"
    layers: tuple = ()                 # LayerPrecision per conv layer
    head: LayerPrecision = LayerPrecision()
    calibrated: bool = False

    def layer(self, i: int) -> LayerPrecision:
        if not self.layers:
            return self.head
        return self.layers[min(i, len(self.layers) - 1)]

    @property
    def is_fp32(self) -> bool:
        return all(lp.compute == "fp32" for lp in self.layers) \
            and self.head.compute == "fp32"

    @property
    def needs_calibration(self) -> bool:
        return (not self.calibrated) and (
            any(lp.compute == "int8" for lp in self.layers)
            or self.head.compute == "int8")

    @property
    def compute_bytes(self) -> float:
        """Mean per-value byte width of the conv datapath — what the
        byte-width-aware cost models consume."""
        if not self.layers:
            return float(self.head.bytes_per_value)
        return float(sum(lp.bytes_per_value for lp in self.layers)
                     / len(self.layers))

    def describe(self) -> dict:
        """JSON-serializable resolved form (Project's config.json)."""
        def one(lp: LayerPrecision) -> dict:
            d = {"compute": lp.compute, "accum": lp.accum,
                 "bytes_per_value": lp.bytes_per_value}
            if lp.compute == "int8":
                d["act_fpx"] = str(lp.act_fpx)
                d["weight_fpx"] = str(lp.weight_fpx)
                if lp.in_fpx is not None:
                    d["in_fpx"] = str(lp.in_fpx)
            return d
        return {"name": self.name, "calibrated": self.calibrated,
                "compute_bytes": self.compute_bytes,
                "layers": [one(lp) for lp in self.layers],
                "head": one(self.head)}


def resolve_policy(spec, num_layers: int) -> PrecisionPolicy:
    """Resolve a precision spec into the per-layer policy: ``None`` or a
    name from ``PRECISIONS`` applies one compute dtype uniformly; an
    existing ``PrecisionPolicy`` passes through (padded/truncated to
    ``num_layers`` if its layer count differs)."""
    if isinstance(spec, PrecisionPolicy):
        if len(spec.layers) == num_layers:
            return spec
        layers = tuple(spec.layer(i) for i in range(num_layers))
        return dataclasses.replace(spec, layers=layers)
    name = spec or "fp32"
    if name not in PRECISIONS:
        raise ValueError(f"unknown precision {name!r}; expected one of "
                         f"{PRECISIONS} or a PrecisionPolicy")
    lp = LayerPrecision(compute=name)
    return PrecisionPolicy(name=name, layers=(lp,) * num_layers, head=lp)


def calibrate_policy(policy: PrecisionPolicy, act_ranges,
                     weight_ranges=None, head_range=None,
                     head_weight_range=None,
                     head_hidden_range=None) -> PrecisionPolicy:
    """Fit the int8 grids from observed max-abs ranges (max-abs scale
    fitting on a calibration batch — ``gnn_model.activation_ranges``
    produces the inputs). fp32/bf16 layers pass through unchanged. The
    head gets two per-tensor grids: ``head_range`` (the pooled input,
    whose add-pooling magnitude dwarfs the rest) fits ``in_fpx`` and
    ``head_hidden_range`` fits the hidden-activation ``act_fpx``."""
    layers = []
    for i, lp in enumerate(policy.layers):
        if lp.compute != "int8":
            layers.append(lp)
            continue
        new = lp
        if act_ranges is not None and i < len(act_ranges):
            new = dataclasses.replace(
                new, act_fpx=fpx_for_max_abs(float(act_ranges[i])))
        if weight_ranges is not None and i < len(weight_ranges):
            new = dataclasses.replace(
                new, weight_fpx=fpx_for_max_abs(float(weight_ranges[i])))
        layers.append(new)
    head = policy.head
    if head.compute == "int8":
        if head_range is not None:
            head = dataclasses.replace(
                head, in_fpx=fpx_for_max_abs(float(head_range)))
        if head_hidden_range is not None:
            head = dataclasses.replace(
                head, act_fpx=fpx_for_max_abs(float(head_hidden_range)))
        if head_weight_range is not None:
            head = dataclasses.replace(
                head, weight_fpx=fpx_for_max_abs(float(head_weight_range)))
    return dataclasses.replace(policy, layers=tuple(layers), head=head,
                               calibrated=True)
