"""GNNModel — the paper's parameterized model architecture (§IV, Fig. 2).

GNN backbone (conv layers + activation + optional skip connections) ->
global pooling (concat of sum/mean/max) -> MLP prediction head. Node- and
graph-level tasks; node and edge input features; arbitrary activation;
per-layer parallelism factors (gnn_p_in/hidden/out, mlp p_in/hidden/out)
which map to kernel tile sizes on TPU.

The paper's Listing-1 API shape is preserved: a single config object the
user trains against (here: init/apply over padded graphs), handed to
``core.project.Project`` for accelerator generation.

Execution tiers: ``apply`` (padded per-graph oracle) -> ``apply_packed``
(one jitted program over a packed GraphBatch) -> ``apply_packed_sharded``
(one SPMD program over a ("data",) device mesh, each device consuming
its own GraphBatch shard — see DESIGN_BATCHING.md §Sharded waves).

Precision: ``gnn_precision`` names the model's PrecisionPolicy (fp32 |
bf16 | int8; ``apply``/``apply_packed`` also accept a fully resolved —
possibly calibrated — ``PrecisionPolicy`` via ``policy=``). Each layer
runs its datapath (weights, streamed activations, kernel tiles) at the
layer's compute width while the residual stream, skip connections, and
pooling stay fp32 — the standard master-precision mixed-precision
discipline. The legacy ``quant`` hook (uniform FPX fake-quant after
every op) is kept as the paper's original testbench semantic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import convs as C
from repro.core import quantization as Q
from repro.core.pooling import global_pooling, segment_global_pooling
from repro.nn.layers import act, linear, linear_plan


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    in_dim: int
    out_dim: int
    hidden_dim: int = 64
    hidden_layers: int = 2
    activation: str = "relu"
    p_in: int = 1
    p_hidden: int = 1
    p_out: int = 1


@dataclasses.dataclass(frozen=True)
class GNNModelConfig:
    """Mirrors gnnb.GNNModel(...) keyword-for-keyword where sensible."""
    graph_input_feature_dim: int
    graph_input_edge_dim: int = 0
    gnn_hidden_dim: int = 64
    gnn_num_layers: int = 2
    gnn_output_dim: int = 64
    gnn_conv: str = "gcn"           # any registered conv (convs.CONV_TYPES)
    gnn_activation: str = "relu"
    gnn_skip_connection: bool = True
    global_pooling: tuple = ("add", "mean", "max")
    mlp_head: MLPConfig | None = None
    output_activation: str | None = None
    task: str = "graph"                      # graph | node
    gnn_p_in: int = 1
    gnn_p_hidden: int = 8
    gnn_p_out: int = 4
    pna_delta: float = 1.0
    # transform/aggregate ordering for the linear convs (convs.DATAFLOWS);
    # "auto" lets the per-layer cost model pick, the explicit values
    # force one ordering for the whole stack
    gnn_dataflow: str = "auto"
    avg_degree: float = 2.0
    # datapath precision spec (quantization.PRECISIONS); resolved to a
    # per-layer PrecisionPolicy by apply/apply_packed (or overridden by
    # their policy= argument with a calibrated policy)
    gnn_precision: str = "fp32"

    def conv_cfg(self, layer: int) -> C.ConvConfig:
        ind = self.graph_input_feature_dim if layer == 0 \
            else self.gnn_hidden_dim
        outd = self.gnn_output_dim if layer == self.gnn_num_layers - 1 \
            else self.gnn_hidden_dim
        p_in = self.gnn_p_in if layer == 0 else self.gnn_p_hidden
        p_out = self.gnn_p_out if layer == self.gnn_num_layers - 1 \
            else self.gnn_p_hidden
        return C.ConvConfig(in_dim=ind, out_dim=outd,
                            edge_dim=self.graph_input_edge_dim,
                            conv=self.gnn_conv,
                            activation=self.gnn_activation,
                            p_in=p_in, p_out=p_out, delta=self.pna_delta,
                            dataflow=self.gnn_dataflow,
                            avg_degree=self.avg_degree)

    @property
    def pooled_dim(self) -> int:
        return self.gnn_output_dim * len(self.global_pooling)


def mlp_head_plan(cfg: MLPConfig, dtype=jnp.float32):
    dims = [cfg.in_dim] + [cfg.hidden_dim] * cfg.hidden_layers \
        + [cfg.out_dim]
    return {f"l{i}": linear_plan(dims[i], dims[i + 1], in_axis=None,
                                 out_axis=None, bias=True, dtype=dtype)
            for i in range(len(dims) - 1)}


def mlp_head_apply(params, x, cfg: MLPConfig, quant: Q.FPX | None = None,
                   lp: Q.LayerPrecision | None = None,
                   record: list | None = None):
    """lp (the policy's head precision) runs the head matmuls at the
    compute width — bf16 casts; int8 re-quantizes the *hidden*
    activations onto the head grid after each linear (the fake-quant
    emulation of an int8 MAC array), while the final accumulator output
    leaves the head dequantized in fp32 — and returns fp32.

    record: when a list, appends each hidden layer's pre-activation
    max-abs — the calibration probe for the head's act grid, kept
    inside the real head path so it can never desynchronize from it."""
    if lp is not None and lp.compute != "fp32":
        params = lp.cast_params(params)
        x = lp.cast_activation(x)
    n = cfg.hidden_layers + 1
    for i in range(n):
        x = linear(params[f"l{i}"], x)
        if quant is not None:
            x = Q.quantize(x, quant)
        if i < n - 1:
            if record is not None:
                record.append(jnp.max(jnp.abs(x)))
            if lp is not None and lp.compute == "int8":
                x = Q.quantize(x, lp.act_fpx)
            x = act(cfg.activation)(x)
    return x.astype(jnp.float32)


def model_plan(cfg: GNNModelConfig, dtype=jnp.float32):
    plan = {"convs": {f"c{i}": C.conv_plan(cfg.conv_cfg(i), dtype)
                      for i in range(cfg.gnn_num_layers)}}
    if cfg.gnn_skip_connection:
        # project skip when dims change (layer0 and final layer)
        for i in range(cfg.gnn_num_layers):
            cc = cfg.conv_cfg(i)
            if cc.in_dim != cc.out_dim:
                plan[f"skip{i}"] = linear_plan(cc.in_dim, cc.out_dim,
                                               in_axis=None, out_axis=None,
                                               dtype=dtype)
    if cfg.task == "graph":
        plan["mlp"] = mlp_head_plan(cfg.mlp_head, dtype)
    return plan


def graph_inputs(batch_el: dict) -> tuple:
    """Unpack one padded graph {node_feat, edge_index, edge_feat,
    num_nodes, num_edges, y} into (g, x, node_mask)."""
    x = batch_el["node_feat"]
    n_max = x.shape[0]
    num_nodes = batch_el["num_nodes"]
    edge_index = batch_el["edge_index"]
    valid_e = edge_index[:, 0] >= 0
    node_mask = jnp.arange(n_max) < num_nodes
    from repro.core.aggregations import degrees
    indeg, outdeg = degrees(edge_index, n_max, valid_e)
    edge_scale, self_scale = C.gcn_normalization(edge_index, indeg, valid_e)
    g = {"edge_index": edge_index, "edge_feat": batch_el.get("edge_feat"),
         "valid_e": valid_e, "in_deg": indeg, "out_deg": outdeg,
         "num_nodes": num_nodes,
         # GCN symmetric-norm scales, hoisted: derived once per batch
         # from static graph fields instead of twice per layer stack
         "gcn_edge_scale": edge_scale, "gcn_self_scale": self_scale}
    return g, x, node_mask


def packed_to_device(batch: dict) -> dict:
    """Host GraphBatch -> device arrays, stripping the host-only target
    buffer ``y`` so it is never traced into the inference program."""
    return {k: jnp.asarray(v) for k, v in batch.items() if k != "y"}


def packed_inputs(batch: dict) -> tuple:
    """Unpack a packed GraphBatch {node_feat (N,F), node_graph_id (N,),
    edge_index (E,2) global ids, edge_feat, graph_valid (G,)} into
    (g, x, node_mask, graph_id). The packed batch is the disjoint union
    graph, so the same conv applies run on it unchanged."""
    x = batch["node_feat"]
    graph_id = batch["node_graph_id"]
    num_graphs = batch["graph_valid"].shape[0]
    node_mask = graph_id < num_graphs
    edge_index = batch["edge_index"]
    valid_e = edge_index[:, 0] >= 0
    # partitioned subgraphs carry precomputed *global* degrees: a halo
    # row's in-edges live on its owning device, so the locally-counted
    # degree would be wrong for the GCN norm of cut edges
    indeg = batch.get("node_in_deg")
    outdeg = batch.get("node_out_deg")
    if indeg is None or outdeg is None:
        from repro.core.aggregations import degrees
        d_in, d_out = degrees(edge_index, x.shape[0], valid_e)
        indeg = d_in if indeg is None else indeg
        outdeg = d_out if outdeg is None else outdeg
    edge_scale, self_scale = C.gcn_normalization(edge_index, indeg, valid_e)
    g = {"edge_index": edge_index, "edge_feat": batch.get("edge_feat"),
         "valid_e": valid_e, "in_deg": indeg, "out_deg": outdeg,
         "num_nodes": jnp.sum(node_mask.astype(jnp.int32)),
         "gcn_edge_scale": edge_scale, "gcn_self_scale": self_scale}
    return g, x, node_mask, graph_id


def resolve_policy(cfg: GNNModelConfig,
                   policy=None) -> Q.PrecisionPolicy:
    """The model's resolved PrecisionPolicy: an explicit (possibly
    calibrated) policy wins, else ``cfg.gnn_precision`` resolves to a
    uniform per-layer policy."""
    return Q.resolve_policy(policy if policy is not None
                            else cfg.gnn_precision, cfg.gnn_num_layers)


def _backbone(params, cfg: GNNModelConfig, g, x, node_mask,
              quant: Q.FPX | None,
              policy: Q.PrecisionPolicy | None = None,
              record: list | None = None, exchange=None):
    """Conv stack + activation + skip, shared by the padded per-graph
    oracle (`apply`) and the packed batch path (`apply_packed`).

    policy: each layer's conv datapath (weights + the tensors entering
    the edge stream) runs at the layer's compute width; the residual
    stream / skip / activation stay fp32. record: when a list, appends
    one max-abs scalar per layer (max over the layer's input and conv
    output) — the calibration probe ``activation_ranges`` consumes.
    exchange: optional (N, F) -> (N, F) hook run between consecutive
    layers (not after the last) — the partitioned path's halo exchange,
    which overwrites replicated boundary rows with their owners' values
    so layer i+1 aggregates over up-to-date neighbors.
    """
    nl = cfg.gnn_num_layers
    for i in range(nl):
        cc = cfg.conv_cfg(i)
        p_i = params["convs"][f"c{i}"]
        x_in = x
        lp = policy.layer(i) if policy is not None else None
        if lp is not None and lp.compute != "fp32":
            cc = dataclasses.replace(cc, precision=lp)
            p_i = lp.cast_params(p_i)
            x_in = lp.cast_activation(x)
        h = C.conv_apply(p_i, g, x_in, cc).astype(jnp.float32)
        if record is not None:
            record.append(jnp.maximum(jnp.max(jnp.abs(x)),
                                      jnp.max(jnp.abs(h))))
        if quant is not None:
            h = Q.quantize(h, quant)
        if cfg.gnn_skip_connection:
            skip = x
            if f"skip{i}" in params:
                skip = linear(params[f"skip{i}"], x)
            h = h + skip
        x = act(cfg.gnn_activation)(h)
        x = x * node_mask[:, None]
        if quant is not None:
            x = Q.quantize(x, quant)
        if exchange is not None and i < nl - 1:
            x = exchange(x)
    return x


def apply(params, cfg: GNNModelConfig, batch_el: dict,
          quant: Q.FPX | None = None, policy=None):
    """Forward one padded graph. quant != None reproduces the fixed-point
    testbench semantics (weights are pre-quantized by the caller);
    policy (or cfg.gnn_precision) selects the per-layer PrecisionPolicy
    datapath."""
    pol = resolve_policy(cfg, policy)
    pol = None if pol.is_fp32 else pol
    g, x, node_mask = graph_inputs(batch_el)
    if quant is not None:
        x = Q.quantize(x, quant)
    x = _backbone(params, cfg, g, x, node_mask, quant, pol)
    if cfg.task == "node":
        return x
    pooled = global_pooling(cfg.global_pooling, x, node_mask)
    if quant is not None:
        pooled = Q.quantize(pooled, quant)
    out = mlp_head_apply(params["mlp"], pooled.astype(x.dtype),
                         cfg.mlp_head, quant,
                         pol.head if pol is not None else None)
    if cfg.output_activation:
        out = act(cfg.output_activation)(out)
    return out


def apply_packed(params, cfg: GNNModelConfig, batch: dict,
                 quant: Q.FPX | None = None, policy=None, *,
                 halo_exchange=None, return_node_features: bool = False):
    """Forward a packed GraphBatch — all graphs in one XLA program.

    Returns (num_graphs, out_dim) for graph tasks (rows where
    ``graph_valid`` is False are padding) or the (N_total, F) node
    embeddings for node tasks. Matches per-graph ``apply`` outputs to
    fp32 tolerance; `apply` stays the single-graph oracle. policy (or
    cfg.gnn_precision) selects the per-layer PrecisionPolicy datapath —
    both paths resolve it identically, so padded-vs-packed parity holds
    at every precision.

    halo_exchange: optional between-layer (N, F) -> (N, F) hook (the
    partitioned path's boundary-row swap; see
    ``make_partitioned_apply``). return_node_features skips pooling and
    the head, returning the post-backbone (N, F) node table — the
    per-device body of the partitioned program, which pools only after
    reassembling the global node order.
    """
    pol = resolve_policy(cfg, policy)
    pol = None if pol.is_fp32 else pol
    g, x, node_mask, graph_id = packed_inputs(batch)
    num_graphs = batch["graph_valid"].shape[0]
    if quant is not None:
        x = Q.quantize(x, quant)
    x = _backbone(params, cfg, g, x, node_mask, quant, pol,
                  exchange=halo_exchange)
    if cfg.task == "node" or return_node_features:
        return x
    pooled = segment_global_pooling(cfg.global_pooling, x, graph_id,
                                    num_graphs, node_mask)
    if quant is not None:
        pooled = Q.quantize(pooled, quant)
    out = mlp_head_apply(params["mlp"], pooled.astype(x.dtype),
                         cfg.mlp_head, quant,
                         pol.head if pol is not None else None)
    if cfg.output_activation:
        out = act(cfg.output_activation)(out)
    return out


def _qp_row(lp: Q.LayerPrecision | None):
    """Per-layer precision row [mode, scale, lo, hi] the residency
    kernel's dynamic cast consumes (residency._cast_dyn) — the exact
    parameters of ``LayerPrecision.cast_activation`` for this layer."""
    if lp is None or lp.compute == "fp32":
        return [0.0, 1.0, 0.0, 0.0]
    if lp.compute == "bf16":
        return [1.0, 1.0, 0.0, 0.0]
    fpx = lp.in_fpx or lp.act_fpx
    return [2.0, fpx.resolution, fpx.min_val, fpx.max_val]


def _pad2(w, fmax):
    return jnp.zeros((fmax, fmax), jnp.float32).at[
        :w.shape[0], :w.shape[1]].set(w.astype(jnp.float32))


def apply_packed_resident(params, cfg: GNNModelConfig, batch: dict,
                          quant: Q.FPX | None = None, policy=None, *,
                          fusion_depth: int = 2,
                          edge_block: int | None = None,
                          interpret: bool | None = None,
                          vmem_bytes: int | None = None):
    """``apply_packed`` with the conv stack executed by the multi-layer
    VMEM-residency kernel: consecutive layers fuse into single kernel
    launches (groups of ``fusion_depth``), the node table staying
    on-chip across layer boundaries instead of round-tripping HBM per
    layer (kernels/fused_gather_aggregate/residency.py).

    Falls back to ``apply_packed`` — bit-identically, since that *is*
    the fallback call — whenever the ``convs.residency_plan`` VMEM
    budget rule says residency is illegal (non-linear-phi conv,
    fusion_depth < 2, working set over budget) or the legacy ``quant``
    testbench hook is set. The resident path always aggregates first at
    the padded table width: exact for fp32 (linearity), within the
    layer dtype's rounding tolerance for bf16/int8 policies (the
    per-layer PrecisionPolicy is emulated in-kernel via dynamic qp rows;
    see docs/KERNELS.md §Residency). Pooling + MLP head run unchanged.
    """
    from repro.core import aggregations as agg_mod
    from repro.kernels.fused_gather_aggregate.residency import (
        fused_layer_stack_pallas)

    pol = resolve_policy(cfg, policy)
    pol = None if pol.is_fp32 else pol
    nl = cfg.gnn_num_layers
    ccs = [cfg.conv_cfg(i) for i in range(nl)]
    eb = edge_block or agg_mod._DEFAULT_EDGE_BLOCK
    g, x, node_mask, graph_id = packed_inputs(batch)
    n = x.shape[0]
    plan = C.residency_plan([(c.in_dim, c.out_dim) for c in ccs], n,
                            cfg.gnn_conv, fusion_depth,
                            quantized=pol is not None, edge_block=eb,
                            vmem_bytes=vmem_bytes)
    if quant is not None or not plan.legal:
        return apply_packed(params, cfg, batch, quant, policy)

    fmax = plan.fmax
    src, dst = g["edge_index"][:, 0], g["edge_index"][:, 1]
    if cfg.gnn_conv == "gcn":
        scale = g["gcn_edge_scale"]
        self_vec = g["gcn_self_scale"]
    else:                                        # sage
        scale = g["valid_e"].astype(jnp.float32)
        self_vec = jnp.zeros((n,), jnp.float32)
    xpad = jnp.zeros((n, fmax), jnp.float32).at[:, :x.shape[1]].set(
        x.astype(jnp.float32))

    for i0 in range(0, nl, plan.depth):
        layers = range(i0, min(i0 + plan.depth, nl))
        wa, wn, wsk, bias, qps = [], [], [], [], []
        for i in layers:
            p_i = params["convs"][f"c{i}"]
            lp = pol.layer(i) if pol is not None else None
            if lp is not None and lp.compute != "fp32":
                p_i = lp.cast_params(p_i)
            qps.append(_qp_row(lp))
            if cfg.gnn_conv == "gcn":
                wa.append(jnp.zeros((fmax, fmax), jnp.float32))
                wn.append(_pad2(p_i["w"]["w"], fmax))
                b_i = p_i["w"]["b"]
            else:
                wa.append(_pad2(p_i["w_self"]["w"], fmax))
                wn.append(_pad2(p_i["w_neigh"]["w"], fmax))
                b_i = p_i["w_self"]["b"]
            bias.append(jnp.zeros((fmax,), jnp.float32).at[
                :b_i.shape[0]].set(b_i.astype(jnp.float32)))
            if not cfg.gnn_skip_connection:
                wsk.append(jnp.zeros((fmax, fmax), jnp.float32))
            elif f"skip{i}" in params:
                # projection skips stay fp32 (the residual-stream rule)
                wsk.append(_pad2(params[f"skip{i}"]["w"], fmax))
            else:
                wsk.append(_pad2(jnp.eye(ccs[i].in_dim), fmax))
        xpad = fused_layer_stack_pallas(
            xpad, src, dst, scale, self_vec,
            node_mask.astype(jnp.float32),
            jnp.stack(wa), jnp.stack(wn), jnp.stack(wsk),
            jnp.stack(bias), jnp.asarray(qps, jnp.float32),
            kind=cfg.gnn_conv, activation=cfg.gnn_activation,
            edge_block=eb,
            interpret=agg_mod._resolve_interpret(interpret),
            has_skip=cfg.gnn_skip_connection,
            quantized=pol is not None)

    x = xpad[:, :ccs[-1].out_dim]
    if cfg.task == "node":
        return x
    num_graphs = batch["graph_valid"].shape[0]
    pooled = segment_global_pooling(cfg.global_pooling, x, graph_id,
                                    num_graphs, node_mask)
    out = mlp_head_apply(params["mlp"], pooled, cfg.mlp_head, None,
                         pol.head if pol is not None else None)
    if cfg.output_activation:
        out = act(cfg.output_activation)(out)
    return out


def stack_shards(shards) -> dict:
    """Host ShardedBatch shards -> one stacked device-ready dict with a
    leading shard dim (num_shards, ...), stripping the host-only ``y``
    like ``packed_to_device``. Accepts a ShardedBatch or a plain list of
    same-shape GraphBatch dicts."""
    shards = getattr(shards, "shards", shards)
    return {k: jnp.stack([jnp.asarray(b[k]) for b in shards])
            for k in shards[0] if k != "y"}


def make_sharded_apply(cfg: GNNModelConfig, mesh,
                       quant: Q.FPX | None = None, policy=None):
    """Build the jitted SPMD program for data-parallel sharded packed
    inference over a 1-D ("data",) mesh (launch.mesh.make_data_mesh).

    Params replicate (distributed.sharding.replicated); the stacked
    batch's leading shard dim splits over "data" (graph_batch_sharding)
    so each device consumes exactly its own GraphBatch shard — the
    per-device program is ``apply_packed`` unchanged, which is why
    sharded outputs match the single-device program to fp32 tolerance
    at every precision and aggregation backend. Graph tasks return
    (num_shards, max_graphs, out_dim) — restore host order with
    ``data.pipeline.gather_shard_outputs``; node tasks return the
    stacked per-shard node tables (num_shards, node_budget, F).

    Trace-time state (the aggregation backend scope) is baked in on the
    first call, like ``apply_packed`` under jit. Hold on to the returned
    callable across waves so XLA compiles exactly once.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import (graph_batch_sharding,
                                            replicated)

    def per_shard(params, batch):
        batch = {k: v[0] for k, v in batch.items()}
        return apply_packed(params, cfg, batch, quant, policy)[None]

    fn = shard_map(per_shard, mesh=mesh,
                   in_specs=(P(), P("data")), out_specs=P("data"),
                   check_rep=False)
    return jax.jit(fn, in_shardings=(replicated(mesh),
                                     graph_batch_sharding(mesh)))


def apply_packed_sharded(params, cfg: GNNModelConfig, shards, mesh=None,
                         quant: Q.FPX | None = None, policy=None):
    """One-shot data-parallel sharded forward: stack ``shards`` (a
    ShardedBatch, a list of same-shape GraphBatch dicts, or an already
    stacked dict) and run them through one SPMD program, one shard per
    device. ``mesh=None`` builds the ("data",) mesh over the first
    num_shards local devices. Retraces on every call — serving and
    benchmark loops should hold on to ``make_sharded_apply`` instead."""
    stacked = shards if isinstance(shards, dict) else stack_shards(shards)
    if mesh is None:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(stacked["node_feat"].shape[0])
    return make_sharded_apply(cfg, mesh, quant, policy)(params, stacked)


def make_partitioned_apply(cfg: GNNModelConfig, mesh,
                           quant: Q.FPX | None = None, policy=None, *,
                           out_rows: int | None = None):
    """Build the jitted SPMD program for intra-graph partitioned
    inference: ONE oversize graph split into per-device subgraphs
    (``data.pipeline.partition_graph``) runs over the same 1-D
    ("data",) mesh as the sharded path.

    The per-device body is ``apply_packed`` unchanged (conv x precision
    x backend parity by construction) with two additions fused around
    it:

    * **halo exchange** between conv layers: each device publishes its
      ``halo_send`` boundary rows, the (halo_budget, F) publish buffers
      all-gather over "data", and every device overwrites its halo rows
      (``halo_recv_src``/``halo_recv_dst``; sentinel indices drop) with
      the owners' freshly-computed values — so layer i+1 aggregates
      over exact neighbor features despite the edge cut;
    * **global reassembly** after the last layer: the per-device node
      tables scatter into global node order via ``node_global_id``
      (each owned row written exactly once), then the padded oracle's
      own ``global_pooling`` + head run over the reassembled buffer —
      which is why partitioned graph outputs match ``apply`` bitwise
      at fp32.

    The build is TWO programs, not one: the SPMD conv stack over the
    mesh, and a single-device tail doing the O(out_rows) reassembly +
    pooling + head. Folding the tail into the SPMD program would
    replicate its full-graph-sized scatter and reductions on every
    device — dead weight that grows with the graph while the per-device
    conv work shrinks with it. The tail is exactly the work the padded
    oracle's own epilogue pays, paid once.

    out_rows sizes the reassembly buffer; pass the source graph's
    padded node-buffer row count (``GraphPartition.padded_nodes``) for
    *bitwise* fp32 parity with the padded oracle — XLA's pooling
    reduction is shape-sensitive, so reducing over a buffer of any
    other size matches only to reassociation tolerance. Defaults to
    ``num_parts * node_budget``.

    Returns ``fn(params, stacked_parts)``: graph tasks yield the
    (out_dim,) output row, node tasks the (out_rows, F) global-order
    node table.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import (graph_batch_sharding,
                                            replicated)

    def per_device(params, batch):
        b = {k: v[0] for k, v in batch.items()}
        send = b.pop("halo_send")
        recv_src = b.pop("halo_recv_src")
        recv_dst = b.pop("halo_recv_dst")
        b.pop("node_global_id")
        b.pop("total_nodes")
        nb = b["node_feat"].shape[0]

        def exchange(x):
            ok = send >= 0
            pub = jnp.where(ok[:, None], x[jnp.clip(send, 0, nb - 1)], 0.0)
            flat = jax.lax.all_gather(pub, "data").reshape(-1, x.shape[-1])
            rows = flat[jnp.clip(recv_src, 0, flat.shape[0] - 1)]
            return x.at[recv_dst].set(rows, mode="drop")

        feats = apply_packed(params, cfg, b, quant, policy,
                             halo_exchange=exchange,
                             return_node_features=True)
        return feats[None]

    conv = shard_map(per_device, mesh=mesh,
                     in_specs=(P(), P("data")), out_specs=P("data"),
                     check_rep=False)
    conv = jax.jit(conv, in_shardings=(replicated(mesh),
                                       graph_batch_sharding(mesh)))

    def tail(params, tbl, gids, total):
        fdim = tbl.shape[-1]
        rows = out_rows or tbl.shape[0] * tbl.shape[1]
        buf = jnp.zeros((rows, fdim), tbl.dtype)
        buf = buf.at[gids.reshape(-1)].set(tbl.reshape(-1, fdim),
                                           mode="drop")
        if cfg.task == "node":
            return buf
        mask = jnp.arange(buf.shape[0]) < total
        pol = resolve_policy(cfg, policy)
        pol = None if pol.is_fp32 else pol
        pooled = global_pooling(cfg.global_pooling, buf, mask)
        if quant is not None:
            pooled = Q.quantize(pooled, quant)
        out = mlp_head_apply(params["mlp"], pooled.astype(buf.dtype),
                             cfg.mlp_head, quant,
                             pol.head if pol is not None else None)
        if cfg.output_activation:
            out = act(cfg.output_activation)(out)
        return out

    tail = jax.jit(tail)

    def fn(params, stacked):
        tbl = conv(params, stacked)                      # (P, NB, F)
        # total_nodes rides as a traced arg, not a python constant —
        # every distinct graph size would otherwise recompile the tail
        return tail(params, tbl,
                    jnp.asarray(stacked["node_global_id"]),
                    jnp.asarray(stacked["total_nodes"])[0])

    return fn


#: compiled partitioned programs keyed by (config/mesh/quant/policy
#: identity, out_rows, num_parts); the value holds the keyed objects so
#: their ids cannot be recycled while the entry lives. Serving calls
#: ``apply_packed_partitioned`` per oversize request — without this, a
#: fresh ``jax.jit`` wrapper per call would recompile every time.
_PARTITIONED_PROGRAMS: dict = {}


def apply_packed_partitioned(params, cfg: GNNModelConfig, partition,
                             mesh=None, quant: Q.FPX | None = None,
                             policy=None):
    """One-shot partitioned forward of one oversize graph: stack a
    ``data.pipeline.GraphPartition``'s parts (or a plain list of
    same-shape part dicts), run the SPMD conv program + single-device
    reassembly tail over a ("data",) mesh (built over the first
    num_parts local devices when ``mesh=None``) and return the graph
    output row — the padded oracle's answer. The compiled programs are
    cached per (cfg, mesh, quant, policy, out_rows, num_parts), so
    serving loops can call this per request without recompiling."""
    parts = getattr(partition, "parts", partition)
    out_rows = getattr(partition, "padded_nodes", 0) or None
    stacked = stack_shards(parts)
    if mesh is None:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(len(parts))
    key = (id(cfg), id(mesh), id(quant), id(policy), out_rows, len(parts))
    hit = _PARTITIONED_PROGRAMS.get(key)
    if hit is None:
        fn = make_partitioned_apply(cfg, mesh, quant, policy,
                                    out_rows=out_rows)
        hit = (fn, (cfg, mesh, quant, policy))
        _PARTITIONED_PROGRAMS[key] = hit
    return hit[0](params, stacked)


def activation_ranges(params, cfg: GNNModelConfig, batch: dict) -> dict:
    """Calibration probe: one fp32 forward over a packed calibration
    batch, recording the max-abs ranges an int8 policy's grids are
    fitted from (``quantization.calibrate_policy``):

      acts[i]      — layer i's streamed tensors (conv input + output)
      weights[i]   — layer i's conv weight leaves
      head         — the pooled head input (graph tasks; 0.0 for node)
      head_hidden  — the head's hidden activations (a separate
                     per-tensor scale: add-pooling makes the input range
                     dwarf the hidden range)
      head_weight  — the MLP-head weight leaves
    """
    def tree_max_abs(tree):
        leaves = [jnp.max(jnp.abs(a)) for a in jax.tree_util.tree_leaves(
            tree) if jnp.issubdtype(a.dtype, jnp.floating)]
        return float(jnp.max(jnp.stack(leaves))) if leaves else 0.0

    g, x, node_mask, graph_id = packed_inputs(batch)
    rec: list = []
    x = _backbone(params, cfg, g, x, node_mask, None, None, record=rec)
    head_range = head_hidden = 0.0
    if cfg.task == "graph":
        num_graphs = batch["graph_valid"].shape[0]
        pooled = segment_global_pooling(cfg.global_pooling, x, graph_id,
                                        num_graphs, node_mask)
        head_range = float(jnp.max(jnp.abs(pooled)))
        head_rec: list = []
        mlp_head_apply(params["mlp"], pooled, cfg.mlp_head,
                       record=head_rec)
        if head_rec:
            head_hidden = float(jnp.max(jnp.stack(head_rec)))
    return {
        "acts": [float(r) for r in rec],
        "weights": [tree_max_abs(params["convs"][f"c{i}"])
                    for i in range(cfg.gnn_num_layers)],
        "head": head_range,
        "head_hidden": head_hidden,
        "head_weight": tree_max_abs(params.get("mlp", {})),
    }


def calibrated_policy(params, cfg: GNNModelConfig, batch: dict,
                      policy=None) -> Q.PrecisionPolicy:
    """Resolve + max-abs-calibrate the model's policy on one packed
    calibration batch (no-op beyond resolution for fp32/bf16)."""
    pol = resolve_policy(cfg, policy)
    if not pol.needs_calibration:
        return pol
    r = activation_ranges(params, cfg, batch)
    return Q.calibrate_policy(pol, r["acts"], r["weights"], r["head"],
                              r["head_weight"], r["head_hidden"])


def apply_batch(params, cfg: GNNModelConfig, batch: dict,
                quant: Q.FPX | None = None):
    """vmapped batched forward over stacked padded graphs."""
    return jax.vmap(lambda el: apply(params, cfg, el, quant))(
        {k: v for k, v in batch.items() if k != "y"})


def mse_loss(params, cfg: GNNModelConfig, batch: dict):
    pred = apply_batch(params, cfg, batch)
    return jnp.mean(jnp.square(pred - batch["y"]))


def mse_loss_packed(params, cfg: GNNModelConfig, batch: dict):
    """MSE over the valid graphs of a packed batch (padding rows masked)."""
    pred = apply_packed(params, cfg,
                        {k: v for k, v in batch.items() if k != "y"})
    w = batch["graph_valid"].astype(pred.dtype)[:, None]
    se = jnp.square(pred - batch["y"]) * w
    denom = jnp.maximum(jnp.sum(w) * pred.shape[-1], 1.0)
    return jnp.sum(se) / denom
