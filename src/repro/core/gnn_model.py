"""GNNModel — the paper's parameterized model architecture (§IV, Fig. 2).

GNN backbone (conv layers + activation + optional skip connections) ->
global pooling (concat of sum/mean/max) -> MLP prediction head. Node- and
graph-level tasks; node and edge input features; arbitrary activation;
per-layer parallelism factors (gnn_p_in/hidden/out, mlp p_in/hidden/out)
which map to kernel tile sizes on TPU.

The paper's Listing-1 API shape is preserved: a single config object the
user trains against (here: init/apply over padded graphs), handed to
``core.project.Project`` for accelerator generation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import convs as C
from repro.core import quantization as Q
from repro.core.pooling import global_pooling, segment_global_pooling
from repro.nn.layers import act, linear, linear_plan


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    in_dim: int
    out_dim: int
    hidden_dim: int = 64
    hidden_layers: int = 2
    activation: str = "relu"
    p_in: int = 1
    p_hidden: int = 1
    p_out: int = 1


@dataclasses.dataclass(frozen=True)
class GNNModelConfig:
    """Mirrors gnnb.GNNModel(...) keyword-for-keyword where sensible."""
    graph_input_feature_dim: int
    graph_input_edge_dim: int = 0
    gnn_hidden_dim: int = 64
    gnn_num_layers: int = 2
    gnn_output_dim: int = 64
    gnn_conv: str = "gcn"                    # gcn | sage | gin | pna
    gnn_activation: str = "relu"
    gnn_skip_connection: bool = True
    global_pooling: tuple = ("add", "mean", "max")
    mlp_head: MLPConfig | None = None
    output_activation: str | None = None
    task: str = "graph"                      # graph | node
    gnn_p_in: int = 1
    gnn_p_hidden: int = 8
    gnn_p_out: int = 4
    pna_delta: float = 1.0
    # transform/aggregate ordering for the linear convs (convs.DATAFLOWS);
    # "auto" lets the per-layer cost model pick, the explicit values
    # force one ordering for the whole stack
    gnn_dataflow: str = "auto"
    avg_degree: float = 2.0

    def conv_cfg(self, layer: int) -> C.ConvConfig:
        ind = self.graph_input_feature_dim if layer == 0 \
            else self.gnn_hidden_dim
        outd = self.gnn_output_dim if layer == self.gnn_num_layers - 1 \
            else self.gnn_hidden_dim
        p_in = self.gnn_p_in if layer == 0 else self.gnn_p_hidden
        p_out = self.gnn_p_out if layer == self.gnn_num_layers - 1 \
            else self.gnn_p_hidden
        return C.ConvConfig(in_dim=ind, out_dim=outd,
                            edge_dim=self.graph_input_edge_dim,
                            conv=self.gnn_conv,
                            activation=self.gnn_activation,
                            p_in=p_in, p_out=p_out, delta=self.pna_delta,
                            dataflow=self.gnn_dataflow,
                            avg_degree=self.avg_degree)

    @property
    def pooled_dim(self) -> int:
        return self.gnn_output_dim * len(self.global_pooling)


def mlp_head_plan(cfg: MLPConfig, dtype=jnp.float32):
    dims = [cfg.in_dim] + [cfg.hidden_dim] * cfg.hidden_layers \
        + [cfg.out_dim]
    return {f"l{i}": linear_plan(dims[i], dims[i + 1], in_axis=None,
                                 out_axis=None, bias=True, dtype=dtype)
            for i in range(len(dims) - 1)}


def mlp_head_apply(params, x, cfg: MLPConfig, quant: Q.FPX | None = None):
    n = cfg.hidden_layers + 1
    for i in range(n):
        x = linear(params[f"l{i}"], x)
        if quant is not None:
            x = Q.quantize(x, quant)
        if i < n - 1:
            x = act(cfg.activation)(x)
    return x


def model_plan(cfg: GNNModelConfig, dtype=jnp.float32):
    plan = {"convs": {f"c{i}": C.conv_plan(cfg.conv_cfg(i), dtype)
                      for i in range(cfg.gnn_num_layers)}}
    if cfg.gnn_skip_connection:
        # project skip when dims change (layer0 and final layer)
        for i in range(cfg.gnn_num_layers):
            cc = cfg.conv_cfg(i)
            if cc.in_dim != cc.out_dim:
                plan[f"skip{i}"] = linear_plan(cc.in_dim, cc.out_dim,
                                               in_axis=None, out_axis=None,
                                               dtype=dtype)
    if cfg.task == "graph":
        plan["mlp"] = mlp_head_plan(cfg.mlp_head, dtype)
    return plan


def graph_inputs(batch_el: dict) -> tuple:
    """Unpack one padded graph {node_feat, edge_index, edge_feat,
    num_nodes, num_edges, y} into (g, x, node_mask)."""
    x = batch_el["node_feat"]
    n_max = x.shape[0]
    num_nodes = batch_el["num_nodes"]
    edge_index = batch_el["edge_index"]
    valid_e = edge_index[:, 0] >= 0
    node_mask = jnp.arange(n_max) < num_nodes
    from repro.core.aggregations import degrees
    indeg, outdeg = degrees(edge_index, n_max, valid_e)
    edge_scale, self_scale = C.gcn_normalization(edge_index, indeg, valid_e)
    g = {"edge_index": edge_index, "edge_feat": batch_el.get("edge_feat"),
         "valid_e": valid_e, "in_deg": indeg, "out_deg": outdeg,
         "num_nodes": num_nodes,
         # GCN symmetric-norm scales, hoisted: derived once per batch
         # from static graph fields instead of twice per layer stack
         "gcn_edge_scale": edge_scale, "gcn_self_scale": self_scale}
    return g, x, node_mask


def packed_to_device(batch: dict) -> dict:
    """Host GraphBatch -> device arrays, stripping the host-only target
    buffer ``y`` so it is never traced into the inference program."""
    return {k: jnp.asarray(v) for k, v in batch.items() if k != "y"}


def packed_inputs(batch: dict) -> tuple:
    """Unpack a packed GraphBatch {node_feat (N,F), node_graph_id (N,),
    edge_index (E,2) global ids, edge_feat, graph_valid (G,)} into
    (g, x, node_mask, graph_id). The packed batch is the disjoint union
    graph, so the same conv applies run on it unchanged."""
    x = batch["node_feat"]
    graph_id = batch["node_graph_id"]
    num_graphs = batch["graph_valid"].shape[0]
    node_mask = graph_id < num_graphs
    edge_index = batch["edge_index"]
    valid_e = edge_index[:, 0] >= 0
    from repro.core.aggregations import degrees
    indeg, outdeg = degrees(edge_index, x.shape[0], valid_e)
    edge_scale, self_scale = C.gcn_normalization(edge_index, indeg, valid_e)
    g = {"edge_index": edge_index, "edge_feat": batch.get("edge_feat"),
         "valid_e": valid_e, "in_deg": indeg, "out_deg": outdeg,
         "num_nodes": jnp.sum(node_mask.astype(jnp.int32)),
         "gcn_edge_scale": edge_scale, "gcn_self_scale": self_scale}
    return g, x, node_mask, graph_id


def _backbone(params, cfg: GNNModelConfig, g, x, node_mask,
              quant: Q.FPX | None):
    """Conv stack + activation + skip, shared by the padded per-graph
    oracle (`apply`) and the packed batch path (`apply_packed`)."""
    for i in range(cfg.gnn_num_layers):
        cc = cfg.conv_cfg(i)
        h = C.conv_apply(params["convs"][f"c{i}"], g, x, cc)
        if quant is not None:
            h = Q.quantize(h, quant)
        if cfg.gnn_skip_connection:
            skip = x
            if f"skip{i}" in params:
                skip = linear(params[f"skip{i}"], x)
            h = h + skip
        x = act(cfg.gnn_activation)(h)
        x = x * node_mask[:, None]
        if quant is not None:
            x = Q.quantize(x, quant)
    return x


def apply(params, cfg: GNNModelConfig, batch_el: dict,
          quant: Q.FPX | None = None):
    """Forward one padded graph. quant != None reproduces the fixed-point
    testbench semantics (weights are pre-quantized by the caller)."""
    g, x, node_mask = graph_inputs(batch_el)
    if quant is not None:
        x = Q.quantize(x, quant)
    x = _backbone(params, cfg, g, x, node_mask, quant)
    if cfg.task == "node":
        return x
    pooled = global_pooling(cfg.global_pooling, x, node_mask)
    if quant is not None:
        pooled = Q.quantize(pooled, quant)
    out = mlp_head_apply(params["mlp"], pooled.astype(x.dtype),
                         cfg.mlp_head, quant)
    if cfg.output_activation:
        out = act(cfg.output_activation)(out)
    return out


def apply_packed(params, cfg: GNNModelConfig, batch: dict,
                 quant: Q.FPX | None = None):
    """Forward a packed GraphBatch — all graphs in one XLA program.

    Returns (num_graphs, out_dim) for graph tasks (rows where
    ``graph_valid`` is False are padding) or the (N_total, F) node
    embeddings for node tasks. Matches per-graph ``apply`` outputs to
    fp32 tolerance; `apply` stays the single-graph oracle.
    """
    g, x, node_mask, graph_id = packed_inputs(batch)
    num_graphs = batch["graph_valid"].shape[0]
    if quant is not None:
        x = Q.quantize(x, quant)
    x = _backbone(params, cfg, g, x, node_mask, quant)
    if cfg.task == "node":
        return x
    pooled = segment_global_pooling(cfg.global_pooling, x, graph_id,
                                    num_graphs, node_mask)
    if quant is not None:
        pooled = Q.quantize(pooled, quant)
    out = mlp_head_apply(params["mlp"], pooled.astype(x.dtype),
                         cfg.mlp_head, quant)
    if cfg.output_activation:
        out = act(cfg.output_activation)(out)
    return out


def apply_batch(params, cfg: GNNModelConfig, batch: dict,
                quant: Q.FPX | None = None):
    """vmapped batched forward over stacked padded graphs."""
    return jax.vmap(lambda el: apply(params, cfg, el, quant))(
        {k: v for k, v in batch.items() if k != "y"})


def mse_loss(params, cfg: GNNModelConfig, batch: dict):
    pred = apply_batch(params, cfg, batch)
    return jnp.mean(jnp.square(pred - batch["y"]))


def mse_loss_packed(params, cfg: GNNModelConfig, batch: dict):
    """MSE over the valid graphs of a packed batch (padding rows masked)."""
    pred = apply_packed(params, cfg,
                        {k: v for k, v in batch.items() if k != "y"})
    w = batch["graph_valid"].astype(pred.dtype)[:, None]
    se = jnp.square(pred - batch["y"]) * w
    denom = jnp.maximum(jnp.sum(w) * pred.shape[-1], 1.0)
    return jnp.sum(se) / denom
