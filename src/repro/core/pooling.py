"""Global graph pooling (paper §V-B): sum / mean / max over valid nodes,
multiple methods combined by concatenation (GlobalPooling(["add","mean",
"max"]) in the paper's API)."""
from __future__ import annotations

import jax.numpy as jnp

POOLINGS = ("add", "sum", "mean", "max")


def global_pool(kind: str, x, node_mask):
    """x: (N, F); node_mask: (N,) bool -> (F,)."""
    m = node_mask[:, None].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if kind in ("add", "sum"):
        return (xf * m).sum(0)
    if kind == "mean":
        return (xf * m).sum(0) / jnp.maximum(m.sum(), 1.0)
    if kind == "max":
        neg = jnp.where(node_mask[:, None], xf, -jnp.inf)
        out = neg.max(0)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(kind)


def global_pooling(kinds, x, node_mask):
    """Concatenation of pooling methods -> (len(kinds) * F,)."""
    return jnp.concatenate([global_pool(k, x, node_mask) for k in kinds])
