"""Global graph pooling (paper §V-B): sum / mean / max over valid nodes,
multiple methods combined by concatenation (GlobalPooling(["add","mean",
"max"]) in the paper's API).

Two forms, matching the two execution formats:
* ``global_pool(ing)`` — one padded graph, masked dense reduction -> (F,).
* ``segment_global_pool(ing)`` — a packed GraphBatch, ``segment_*``
  reduction keyed by per-node graph_id -> (num_graphs, F). Empty or
  fully-padded graphs zero-fill, identical to the dense form.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.aggregations import segment_aggregate

POOLINGS = ("add", "sum", "mean", "max")

_SEGMENT_AGG = {"add": "sum", "sum": "sum", "mean": "mean", "max": "max"}


def global_pool(kind: str, x, node_mask):
    """x: (N, F); node_mask: (N,) bool -> (F,)."""
    m = node_mask[:, None].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if kind in ("add", "sum"):
        return (xf * m).sum(0)
    if kind == "mean":
        return (xf * m).sum(0) / jnp.maximum(m.sum(), 1.0)
    if kind == "max":
        neg = jnp.where(node_mask[:, None], xf, -jnp.inf)
        out = neg.max(0)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(kind)


def global_pooling(kinds, x, node_mask):
    """Concatenation of pooling methods -> (len(kinds) * F,)."""
    return jnp.concatenate([global_pool(k, x, node_mask) for k in kinds])


def segment_global_pool(kind: str, x, graph_id, num_graphs: int,
                        node_valid=None):
    """x: (N_total, F) packed nodes; graph_id: (N_total,) int32 ->
    (num_graphs, F). Padding slots (graph_id == num_graphs) are dropped."""
    if kind not in _SEGMENT_AGG:
        raise ValueError(kind)
    return segment_aggregate(_SEGMENT_AGG[kind], x, graph_id, num_graphs,
                             node_valid)


def segment_global_pooling(kinds, x, graph_id, num_graphs: int,
                           node_valid=None):
    """Concatenated pooling over a packed batch -> (num_graphs,
    len(kinds) * F)."""
    return jnp.concatenate(
        [segment_global_pool(k, x, graph_id, num_graphs, node_valid)
         for k in kinds], axis=-1)
