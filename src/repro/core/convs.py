"""Message-passing graph convolutions (paper §V-A, Fig. 3).

Every conv follows the explicit gather -> phi -> aggregate -> gamma
dataflow over padded COO graphs, which is what lets GNNBuilder support
anisotropic layers (PNA) that SpMM accelerators cannot express.

Kernels: GCN [23], GraphSAGE [24], GIN(E) [26], PNA [27] — the paper's
Table II set — plus GAT [25], the attention conv the GNN-acceleration
survey names as the standard coverage axis (per-edge softmax is a new
reduction shape: ``kernels/segment_softmax``). Each provides
``plan(cfg)`` + ``apply(params, g, x)``, where ``g`` is a dict
{edge_index (E,2), edge_feat (E,Fe), num_nodes, in_deg, out_deg,
valid_e} with static max shapes (MAX_NODES/MAX_EDGES analogue).

Convs are *registered*, not hard-wired: ``register_conv`` records each
conv's (plan, apply) pair and capability flags in ``CONV_REGISTRY``
(``ConvSpec``), and everything downstream — the dataflow planner, the
residency rule, ``dse.SPACE["conv"]``, the perf-model conv one-hots,
and the test parity grids — enumerates convs from the registry. The
legacy ``CONV_TYPES`` / ``REORDERABLE_CONVS`` / ``RESIDENT_CONVS``
tuples survive as registry-derived views.

The same applies serve both execution formats: a single padded graph and
a packed GraphBatch (many graphs in one flat buffer). A packed batch is
just the disjoint union graph — edge_index holds *global* node ids, so
message passing never crosses graph boundaries and the segment reductions
drop padding edges (src == -1) via ``valid_e``.

Linear-phi convs (GCN/SAGE) additionally carry a *dataflow* choice —
transform-then-aggregate vs aggregate-then-transform. Because their phi
commutes with the (linear) aggregation, either order is exact, but the
edge stream moves ``F_agg``-wide messages, so aggregating at
``min(F_in, F_out)`` width cuts both per-edge bandwidth and matmul
traffic (the aggregate-vs-transform reordering of the GNN-acceleration
survey). ``resolve_dataflow`` picks the cheaper order from a closed-form
cost model over (in_dim, out_dim, avg_degree); ``dataflow="auto"`` can be
overridden per layer stack via ``ConvConfig.dataflow`` /
``GNNModelConfig.gnn_dataflow`` / ``Project(dataflow=...)``.

Every conv also carries a per-layer precision (``ConvConfig.precision``,
a ``quantization.LayerPrecision`` resolved by the model-level
``PrecisionPolicy``): the tensor entering the edge stream is stored and
streamed at the layer's compute width (bf16 / int8 tiles through the
precision-polymorphic aggregation dispatch), while accumulation — and
the model's residual stream — stay fp32. The byte width also enters the
dataflow cost model: the edge-stream term of ``dataflow_cost`` scales
with bytes-per-value, so low-precision layers shrink exactly the term
the reordering optimizes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import aggregations as agg_mod
from repro.core.quantization import LayerPrecision
from repro.nn.layers import act, linear, linear_plan
from repro.nn.param import ParamSpec

PNA_AGGS = ("mean", "min", "max", "std")
PNA_SCALERS = ("identity", "amplification", "attenuation")

DATAFLOWS = ("auto", "aggregate_first", "transform_first")

PRECISION_GRID = ("fp32", "bf16", "int8")


# ------------------------------------------------------- conv registry --
@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One conv's capability contract — the single place the planner,
    DSE, perf model, and the parity grids enumerate convs from
    (docs/KERNELS.md, docs/DSE.md). Adding a conv is one
    ``register_conv`` call next to its plan/apply pair; nothing else in
    the stack hard-codes conv names."""
    name: str
    plan: object          # (ConvConfig, dtype) -> param plan
    apply: object         # (params, g, x, ConvConfig) -> (N, F_out)
    # phi is a plain linear map: aggregation commutes with the
    # transform, so the dataflow planner may reorder the layer
    reorderable: bool = False
    # the multi-layer VMEM-residency kernel can execute it (linear phi +
    # a single scalar per edge); must stay in sync with
    # kernels.fused_gather_aggregate.residency.RESIDENT_KINDS
    resident: bool = False
    # carries a per-edge logit/softmax stage (segment_softmax): adds the
    # attention term to dataflow_cost and excludes the logit math from
    # int8 (the weights themselves stay fp32 at every policy)
    attention: bool = False
    # PrecisionPolicy grid the conv's datapath supports (the parity
    # harness precision axis); attention convs still list int8 — only
    # their projection/aggregate stream quantizes, never the softmax
    precisions: tuple = PRECISION_GRID
    # partitioned-vs-padded-oracle parity holds *bitwise* at fp32: the
    # conv's per-segment reductions preserve the edge stream's relative
    # order on every device (the serve-path acceptance contract)
    partition_bitwise: bool = False
    # enumerated in dse.SPACE["conv"] / perf-model conv one-hots
    dse: bool = True


CONV_REGISTRY: dict[str, ConvSpec] = {}
_REGISTRY_LISTENERS: list = []

# registry-derived capability tuples, rebuilt by every (un)register call
# — read these as ``convs.CONV_TYPES`` (attribute access), not via
# ``from ... import`` snapshots, so late registrations stay visible
CONV_TYPES: tuple = ()
REORDERABLE_CONVS: tuple = ()
RESIDENT_CONVS: tuple = ()


def _registry_changed():
    global CONV_TYPES, REORDERABLE_CONVS, RESIDENT_CONVS
    CONV_TYPES = tuple(CONV_REGISTRY)
    REORDERABLE_CONVS = tuple(n for n, s in CONV_REGISTRY.items()
                              if s.reorderable)
    RESIDENT_CONVS = tuple(n for n, s in CONV_REGISTRY.items()
                           if s.resident)
    for fn in list(_REGISTRY_LISTENERS):
        fn()


def register_conv(name: str, plan, apply, **caps) -> ConvSpec:
    """Register a conv's (plan, apply) pair plus capability flags
    (``ConvSpec`` fields). Derived enumerations — ``CONV_TYPES``,
    ``dse.SPACE["conv"]``, ``perf_model.FEATURE_NAMES`` conv one-hots,
    the parity-grid axes — rebuild immediately."""
    spec = ConvSpec(name=name, plan=plan, apply=apply, **caps)
    CONV_REGISTRY[name] = spec
    _registry_changed()
    return spec


def unregister_conv(name: str) -> None:
    del CONV_REGISTRY[name]
    _registry_changed()


def conv_spec(name: str) -> ConvSpec:
    try:
        return CONV_REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown conv {name!r}; registered: "
                         f"{CONV_TYPES}") from None


def on_registry_change(fn) -> None:
    """Subscribe to registry mutations (dse / perf_model derive their
    conv axes through this). The callback takes no arguments and runs
    synchronously inside every (un)register call."""
    _REGISTRY_LISTENERS.append(fn)

# word-equivalence factor between the two cost-model currencies: at the
# TPUTarget roofline (819 GB/s HBM, 197 TFLOP/s) one fp32 word moved
# costs the same time as ~480 MACs, so compute terms divide by this to
# land in the same per-node units as the streaming term
_MACS_PER_WORD = 480.0


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    in_dim: int
    out_dim: int
    edge_dim: int = 0
    conv: str = "gcn"
    activation: str = "relu"
    # hardware parallelism factors (paper p_in/p_out -> kernel tile sizes)
    p_in: int = 1
    p_out: int = 1
    delta: float = 1.0        # PNA log-degree normalizer (avg log degree)
    # transform/aggregate ordering for linear convs (resolve_dataflow)
    dataflow: str = "auto"
    avg_degree: float = 2.0   # dataset statistic driving the cost model
    # per-layer datapath precision (PrecisionPolicy.layer(i)); the
    # default is the fp32 identity
    precision: LayerPrecision = LayerPrecision()


def gather_compute_flops(num_nodes: int, num_edges: int, feat_dim: int,
                         gather_mode: str = "dma",
                         node_block: int = 128) -> float:
    """Modeled FLOPs the gather+aggregate stage itself spends on one
    layer's edge sweep — the term the pre-v2 cost model omitted (it
    counted bytes only, which made the legacy one-hot kernel "win" on
    paper while losing 40x on the clock).

    "onehot": every (node_tile, edge_tile) grid step builds and
    contracts dense one-hots — ``2 * EB * F * (N + NB)`` MACs-as-FLOPs
    per step — so the sweep costs
    ``2 * E * F * (N + node_block) * ceil(N / node_block)``; at realistic
    N this is compute-bound by orders of magnitude. "dma" gathers each
    row by dynamic slice: ~3 FLOPs per message element (scale multiply +
    accumulate + count), linear in ``E * F``. The materialized XLA path
    has the same ~3 E F compute shape; it pays in message-tensor HBM
    bytes instead (see benchmarks/fused_gather.py)."""
    if gather_mode == "onehot":
        node_tiles = -(-num_nodes // node_block)
        return 2.0 * num_edges * feat_dim * (num_nodes + node_block) \
            * node_tiles
    if gather_mode == "dma":
        return 3.0 * num_edges * feat_dim
    raise ValueError(gather_mode)


def dataflow_cost(in_dim: int, out_dim: int, avg_degree: float,
                  msg_bytes: float = 4.0, gather_mode: str = "dma",
                  num_nodes: int = 1024, node_block: int = 128,
                  attention: bool = False) -> dict:
    """Per-node cost (fp32-word-equivalents moved through the edge
    pipeline + MACs/F) of each ordering. The W matmul costs
    ``in_dim * out_dim`` MACs per node either way; the edge stream
    carries ``avg_degree`` messages per node at the aggregation width —
    F_in when aggregating first, F_out when transforming first — and at
    the layer's storage width: ``msg_bytes`` (the PrecisionPolicy byte
    width, 4 = fp32) scales the streaming term, so low-precision layers
    shrink exactly what the reordering optimizes. The degree scales how
    much the reordering matters; the sign of the difference is
    ``out_dim - in_dim``.

    The gather stage's own compute (``gather_compute_flops``) rides on
    the same per-message-element axis, converted to word-equivalents via
    the roofline ratio ``_MACS_PER_WORD``: negligible for "dma"
    (~0.003 words/element — the v2 kernel is bandwidth-bound), dominant
    for "onehot" (its dense contractions grow with ``num_nodes``), so
    ordering decisions stay honest under either kernel generation.

    ``attention`` adds the logit/softmax term of attention convs
    (registry ``ConvSpec.attention``): per in-edge, one fp32 logit read
    plus one fp32 weight write (the softmax weights never quantize, so
    this term does *not* scale with ``msg_bytes``) and the online-softmax
    arithmetic (~8 flops/edge: max, two exps, multiply-accumulate,
    divide). Width-independent, so it shifts both orderings equally —
    attention convs are not reorderable anyway (the softmax pins the
    aggregation to the projected width) — but it keeps the roofline and
    the DSE's modeled latency honest about what a gat layer streams."""
    matmul = in_dim * out_dim
    gflops = gather_compute_flops(num_nodes, avg_degree, 1.0,
                                  gather_mode, node_block)
    stream = avg_degree * (msg_bytes / 4.0) + gflops / 2.0 / _MACS_PER_WORD
    attn = avg_degree * (2.0 + 8.0 / 2.0 / _MACS_PER_WORD) \
        if attention else 0.0
    return {"aggregate_first": stream * in_dim + matmul + attn,
            "transform_first": stream * out_dim + matmul + attn}


def halo_comm_bytes(cut_edges: float, feat_dim: int,
                    bytes_per_value: float, num_layers: int) -> float:
    """Modeled inter-device traffic of intra-graph partitioned inference
    (pipeline.partition_graph): every message-passing boundary except the
    last exchanges the boundary-node rows the cut edges read, one feature
    row per cut edge at the layer's storage width. This is the comm-cost
    term the DSE ``partition`` axis is priced with — the same formula
    ``GraphPartition.comm_bytes`` reports for a concrete cut, here fed
    with a modeled cut so the fitted models can featurize designs that
    were never partitioned."""
    return float(cut_edges) * feat_dim * bytes_per_value \
        * max(num_layers - 1, 0)


def resolve_dataflow(cfg: ConvConfig) -> str:
    """Planner: the concrete ordering this conv layer executes with."""
    if cfg.dataflow not in DATAFLOWS:
        raise ValueError(cfg.dataflow)
    if cfg.conv not in REORDERABLE_CONVS:
        return "aggregate_first"
    if cfg.dataflow != "auto":
        return cfg.dataflow
    cost = dataflow_cost(cfg.in_dim, cfg.out_dim, cfg.avg_degree,
                         cfg.precision.bytes_per_value,
                         attention=conv_spec(cfg.conv).attention)
    return "transform_first" \
        if cost["transform_first"] < cost["aggregate_first"] \
        else "aggregate_first"


@dataclasses.dataclass(frozen=True)
class ResidencyPlan:
    """Planner verdict for the multi-layer VMEM-resident conv stack
    (kernels.fused_gather_aggregate.residency): whether keeping the node
    table on-chip across ``depth`` consecutive layers fits the VMEM
    budget, and the footprint arithmetic behind the decision. Recorded
    verbatim in Project config.json so a generated accelerator documents
    why residency did (not) engage."""
    legal: bool
    depth: int            # layers fused per kernel launch (min(req, L))
    fmax: int             # padded table width (lane-aligned max dim)
    vmem_required: int    # bytes at the widest point of the fused group
    vmem_budget: int      # bytes the planner allows (frac * target VMEM)
    reason: str


def residency_plan(layer_dims, node_budget: int, conv: str,
                   fusion_depth: int, *, quantized: bool = False,
                   edge_block: int = 128, vmem_bytes: int | None = None,
                   vmem_frac: float = 0.75) -> ResidencyPlan:
    """VMEM-budget rule deciding when multi-layer residency is legal.

    layer_dims: [(in_dim, out_dim), ...] for the conv stack;
    node_budget: packed-batch node-table rows; quantized: a non-fp32
    policy adds the quantized shadow table. The resident working set at
    the widest point is the fp32 table, the input block, the aggregate
    accumulator (and the shadow when quantized) — each
    ``node_budget * fmax * 4`` bytes with ``fmax`` the lane-aligned max
    layer width — plus the mean-count column and the double-buffered
    per-layer weight/scale blocks. Legal only for ``RESIDENT_CONVS``
    (linear phi, one scalar per edge) at ``fusion_depth > 1``, and only
    when the working set fits ``vmem_frac`` of the target's VMEM
    (default ``core.project.TPUTarget.vmem_bytes``) — the remaining
    fraction is headroom for Mosaic's own spills."""
    if vmem_bytes is None:
        from repro.core.project import TPUTarget
        vmem_bytes = int(TPUTarget().vmem_bytes)
    budget = int(vmem_bytes * vmem_frac)
    depth = max(1, min(int(fusion_depth), len(layer_dims)))
    fmax = max(max(d) for d in layer_dims)
    fmax = -(-fmax // 128) * 128
    tables = 3 + (1 if quantized else 0)       # x0, xout, aggr[, xq]
    required = (tables * node_budget * fmax * 4
                + node_budget * 4               # mean count column
                + 2 * node_budget * 4           # self-scale + node mask
                + 2 * (3 * fmax * fmax + fmax + 128) * 4  # dbl-buf weights
                + 2 * edge_block * 4)           # dbl-buf edge scales
    if conv not in RESIDENT_CONVS:
        return ResidencyPlan(False, depth, fmax, required, budget,
                             f"conv {conv!r} not in {RESIDENT_CONVS}")
    if depth < 2:
        return ResidencyPlan(False, depth, fmax, required, budget,
                             "fusion_depth < 2: nothing to keep resident")
    if required > budget:
        return ResidencyPlan(False, depth, fmax, required, budget,
                             f"working set {required} B exceeds "
                             f"{budget} B VMEM budget")
    return ResidencyPlan(True, depth, fmax, required, budget,
                         f"{required} B fits {budget} B VMEM budget")


def _gather(x, idx):
    return jnp.take(x, jnp.maximum(idx, 0), axis=0)


def edge_endpoints(g):
    """(src, dst) columns of the padded COO edge buffer; -1 on padding."""
    return g["edge_index"][:, 0], g["edge_index"][:, 1]


def gcn_normalization(edge_index, in_deg, valid=None):
    """Precompute the GCN symmetric-norm scales from static graph fields:
    per-edge ``1/sqrt(d_u d_v)`` and per-node self-loop ``1/d_v``
    (degrees include the self loop). Hoisted out of ``gcn_apply`` so a
    layer stack computes it once per batch — ``graph_inputs`` /
    ``packed_inputs`` stash the result on ``g`` as ``gcn_edge_scale`` /
    ``gcn_self_scale``, shared by the fused and materialized paths."""
    src, dst = edge_index[:, 0], edge_index[:, 1]
    if valid is None:
        valid = src >= 0
    inv = jax.lax.rsqrt(jnp.maximum(in_deg + 1.0, 1e-12))
    edge_scale = _gather(inv, src) * _gather(inv, dst)
    edge_scale = jnp.where(valid, edge_scale, 0.0)
    return edge_scale, inv * inv


def _gcn_scales(g):
    es, ss = g.get("gcn_edge_scale"), g.get("gcn_self_scale")
    if es is None or ss is None:    # direct conv_apply callers
        es, ss = gcn_normalization(g["edge_index"], g["in_deg"],
                                   g.get("valid_e"))
    return es, ss


# ------------------------------------------------------------------ GCN --
def gcn_plan(cfg: ConvConfig, dtype=jnp.float32):
    return {"w": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                             out_axis="mlp", bias=True, dtype=dtype)}


def gcn_apply(params, g, x, cfg: ConvConfig):
    """x' = W (sum_u x_u / sqrt(d_u d_v)) + b  (self loops included).

    The symmetric norm is a per-edge scalar, so the whole layer is
    W A x for a fixed weighted adjacency A — ``resolve_dataflow`` picks
    W (A x) + b (aggregate_first) or A (W x) + b (transform_first); both
    lower through the fused gather->scale->aggregate pipeline."""
    src, dst = edge_endpoints(g)
    n = x.shape[0]
    edge_scale, self_scale = _gcn_scales(g)
    agg_first = resolve_dataflow(cfg) == "aggregate_first"
    h = x if agg_first else x @ params["w"]["w"]  # transform at min width
    aggr = agg_mod.gather_aggregate("sum", h, src, dst, n, g["valid_e"],
                                    edge_scale, precision=cfg.precision)
    aggr = aggr + h.astype(jnp.float32) * self_scale[:, None]  # self loop
    if agg_first:
        return linear(params["w"], aggr.astype(x.dtype))       # gamma
    return aggr.astype(x.dtype) + params["w"]["b"]


# ------------------------------------------------------------ GraphSAGE --
def sage_plan(cfg: ConvConfig, dtype=jnp.float32):
    return {
        "w_self": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                              out_axis="mlp", bias=True, dtype=dtype),
        "w_neigh": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                               out_axis="mlp", dtype=dtype),
    }


def sage_apply(params, g, x, cfg: ConvConfig):
    """x' = W1 x_v + W2 mean_u(x_u)  (flexible aggregation family).

    mean is linear, so W2 mean(x_u) == mean(W2 x_u) exactly —
    ``resolve_dataflow`` aggregates at min(F_in, F_out) width."""
    src, dst = edge_endpoints(g)
    agg_first = resolve_dataflow(cfg) == "aggregate_first"
    h = x if agg_first else x @ params["w_neigh"]["w"]
    aggr = agg_mod.gather_aggregate("mean", h, src, dst, x.shape[0],
                                    g["valid_e"], precision=cfg.precision)
    neigh = linear(params["w_neigh"], aggr.astype(x.dtype)) if agg_first \
        else aggr.astype(x.dtype)
    return linear(params["w_self"], x) + neigh


# ------------------------------------------------------------- GIN(E) ---
def gin_plan(cfg: ConvConfig, dtype=jnp.float32):
    p = {
        "eps": ParamSpec((), jnp.float32, (), init="zeros"),
        "mlp1": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                            out_axis="mlp", bias=True, dtype=dtype),
        "mlp2": linear_plan(cfg.out_dim, cfg.out_dim, in_axis="mlp",
                            out_axis="mlp", bias=True, dtype=dtype),
    }
    if cfg.edge_dim:
        p["w_edge"] = linear_plan(cfg.edge_dim, cfg.in_dim, in_axis=None,
                                  out_axis="embed", dtype=dtype)
    return p


def gin_apply(params, g, x, cfg: ConvConfig):
    """x' = MLP((1+eps) x_v + sum_u relu(x_u + W_e e_uv)) — edge features
    make this inexpressible as SpMM (paper Table II)."""
    src, dst = edge_endpoints(g)
    if "w_edge" in params:
        # edge-feature phi is nonlinear per edge: keep the materialized
        # message path (the fused kernel's scale slot cannot express it)
        msg = jax.nn.relu(_gather(x, src)
                          + linear(params["w_edge"], g["edge_feat"]))
        aggr = agg_mod.segment_aggregate("sum", msg, dst, x.shape[0],
                                         g["valid_e"],
                                         precision=cfg.precision)
    else:
        aggr = agg_mod.gather_aggregate("sum", x, src, dst, x.shape[0],
                                        g["valid_e"],
                                        precision=cfg.precision)
    h = (1.0 + params["eps"]) * x + aggr.astype(x.dtype)
    h = act(cfg.activation)(linear(params["mlp1"], h))
    return linear(params["mlp2"], h)


# ---------------------------------------------------------------- PNA ---
def pna_plan(cfg: ConvConfig, dtype=jnp.float32):
    tower_in = cfg.in_dim * len(PNA_AGGS) * len(PNA_SCALERS)
    p = {
        "pre": linear_plan(2 * cfg.in_dim + cfg.edge_dim, cfg.in_dim,
                           in_axis="embed", out_axis="mlp", bias=True,
                           dtype=dtype),
        "post": linear_plan(tower_in + cfg.in_dim, cfg.out_dim,
                            in_axis="embed", out_axis="mlp", bias=True,
                            dtype=dtype),
    }
    return p


def pna_apply(params, g, x, cfg: ConvConfig):
    """Principal Neighbourhood Aggregation: message MLP phi(x_v, x_u, e),
    4 aggregators x 3 degree scalers, then gamma on [x_v ; towers]."""
    src, dst = edge_endpoints(g)
    n = x.shape[0]
    h_src = _gather(x, src)
    h_dst = _gather(x, dst)
    feats = [h_dst, h_src]
    if cfg.edge_dim:
        feats.append(g["edge_feat"].astype(x.dtype))
    msg = act(cfg.activation)(
        linear(params["pre"], jnp.concatenate(feats, axis=-1)))
    towers = [agg_mod.segment_aggregate(a, msg, dst, n, g["valid_e"],
                                        precision=cfg.precision)
              for a in PNA_AGGS]
    deg = jnp.maximum(g["in_deg"], 1.0)
    logd = jnp.log(deg + 1.0)[:, None]
    scaled = []
    for t in towers:
        scaled += [t, t * (logd / cfg.delta), t * (cfg.delta / logd)]
    out = jnp.concatenate([x.astype(jnp.float32)] + scaled, axis=-1)
    return linear(params["post"], out.astype(x.dtype))


# ---------------------------------------------------------------- GAT ---
def gat_plan(cfg: ConvConfig, dtype=jnp.float32):
    p = {
        "w": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                         out_axis="mlp", bias=True, dtype=dtype),
        "w_self": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                              out_axis="mlp", dtype=dtype),
        "a_src": ParamSpec((cfg.out_dim,), dtype, ("mlp",)),
        "a_dst": ParamSpec((cfg.out_dim,), dtype, ("mlp",)),
    }
    if cfg.edge_dim:
        p["a_edge"] = linear_plan(cfg.edge_dim, 1, in_axis=None,
                                  out_axis=None, dtype=dtype)
    return p


def gat_apply(params, g, x, cfg: ConvConfig):
    """x' = W_self x_v + sum_u alpha_uv (W x_u) + b, with
    alpha = softmax_v(LeakyReLU(a_src.(W x_u) + a_dst.(W x_v)
    [+ a_e.e_uv])) — the root-weight GAT variant (no implicit self
    loops; the explicit W_self path keeps isolated nodes informative).

    The per-dst softmax is the new reduction shape: logits stream
    through ``segment_softmax`` (per-segment online max/exp-sum — the
    ``kernels/segment_softmax`` Pallas machine under backend="pallas"),
    and the resulting per-edge weight rides the fused gather tier's
    existing scale slot, exactly where the GCN symmetric norm sits — so
    the (E, F) message tensor still never materializes on the Pallas
    path. Attention math is fp32 at every PrecisionPolicy: bf16/int8
    quantize the projection and the aggregate message stream only (the
    documented int8 exclusion, docs/KERNELS.md)."""
    src, dst = edge_endpoints(g)
    n = x.shape[0]
    h = x @ params["w"]["w"]                   # projection (policy width)
    hf = h.astype(jnp.float32)
    s_src = hf @ params["a_src"].astype(jnp.float32)
    s_dst = hf @ params["a_dst"].astype(jnp.float32)
    logits = _gather(s_src, src) + _gather(s_dst, dst)
    if "a_edge" in params:
        logits = logits + (g["edge_feat"].astype(jnp.float32)
                           @ params["a_edge"]["w"].astype(
                               jnp.float32))[:, 0]
    logits = jax.nn.leaky_relu(logits, 0.2)
    alpha = agg_mod.segment_softmax(logits, dst, n, g["valid_e"])
    aggr = agg_mod.gather_aggregate("sum", h, src, dst, n, g["valid_e"],
                                    alpha, precision=cfg.precision)
    return linear(params["w_self"], x) + aggr.astype(x.dtype) \
        + params["w"]["b"]


register_conv("gcn", gcn_plan, gcn_apply, reorderable=True, resident=True,
              partition_bitwise=True)
register_conv("sage", sage_plan, sage_apply, reorderable=True,
              resident=True)
register_conv("gin", gin_plan, gin_apply)
register_conv("pna", pna_plan, pna_apply)
register_conv("gat", gat_plan, gat_apply, attention=True,
              partition_bitwise=True)


def conv_plan(cfg: ConvConfig, dtype=jnp.float32):
    return conv_spec(cfg.conv).plan(cfg, dtype)


def conv_apply(params, g, x, cfg: ConvConfig):
    return conv_spec(cfg.conv).apply(params, g, x, cfg)
