"""Message-passing graph convolutions (paper §V-A, Fig. 3).

Every conv follows the explicit gather -> phi -> aggregate -> gamma
dataflow over padded COO graphs, which is what lets GNNBuilder support
anisotropic layers (PNA) that SpMM accelerators cannot express.

Kernels: GCN [23], GraphSAGE [24], GIN(E) [26], PNA [27] — the paper's
Table II set. Each provides ``plan(cfg)`` + ``apply(params, g, x)``, where
``g`` is a dict {edge_index (E,2), edge_feat (E,Fe), num_nodes, in_deg,
out_deg, valid_e} with static max shapes (MAX_NODES/MAX_EDGES analogue).

The same applies serve both execution formats: a single padded graph and
a packed GraphBatch (many graphs in one flat buffer). A packed batch is
just the disjoint union graph — edge_index holds *global* node ids, so
message passing never crosses graph boundaries and the segment reductions
drop padding edges (src == -1) via ``valid_e``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import aggregations as agg_mod
from repro.nn.layers import act, linear, linear_plan
from repro.nn.param import ParamSpec

CONV_TYPES = ("gcn", "sage", "gin", "pna")
PNA_AGGS = ("mean", "min", "max", "std")
PNA_SCALERS = ("identity", "amplification", "attenuation")


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    in_dim: int
    out_dim: int
    edge_dim: int = 0
    conv: str = "gcn"
    activation: str = "relu"
    # hardware parallelism factors (paper p_in/p_out -> kernel tile sizes)
    p_in: int = 1
    p_out: int = 1
    delta: float = 1.0        # PNA log-degree normalizer (avg log degree)


def _gather(x, idx):
    return jnp.take(x, jnp.maximum(idx, 0), axis=0)


def edge_endpoints(g):
    """(src, dst) columns of the padded COO edge buffer; -1 on padding."""
    return g["edge_index"][:, 0], g["edge_index"][:, 1]


# ------------------------------------------------------------------ GCN --
def gcn_plan(cfg: ConvConfig, dtype=jnp.float32):
    return {"w": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                             out_axis="mlp", bias=True, dtype=dtype)}


def gcn_apply(params, g, x, cfg: ConvConfig):
    """x' = W (sum_u x_u / sqrt(d_u d_v)) + b  (self loops included)."""
    src, dst = edge_endpoints(g)
    n = x.shape[0]
    deg = g["in_deg"] + 1.0                       # +1 for self loop
    inv = jax.lax.rsqrt(jnp.maximum(deg, 1e-12))
    msg = _gather(x * inv[:, None], src)          # phi: normalized gather
    aggr = agg_mod.segment_aggregate("sum", msg, dst, n, g["valid_e"])
    aggr = (aggr + x * inv[:, None]) * inv[:, None]   # self loop + norm
    return linear(params["w"], aggr.astype(x.dtype))  # gamma


# ------------------------------------------------------------ GraphSAGE --
def sage_plan(cfg: ConvConfig, dtype=jnp.float32):
    return {
        "w_self": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                              out_axis="mlp", bias=True, dtype=dtype),
        "w_neigh": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                               out_axis="mlp", dtype=dtype),
    }


def sage_apply(params, g, x, cfg: ConvConfig):
    """x' = W1 x_v + W2 mean_u(x_u)  (flexible aggregation family)."""
    src, dst = edge_endpoints(g)
    msg = _gather(x, src)
    aggr = agg_mod.segment_aggregate("mean", msg, dst, x.shape[0],
                                     g["valid_e"])
    return linear(params["w_self"], x) \
        + linear(params["w_neigh"], aggr.astype(x.dtype))


# ------------------------------------------------------------- GIN(E) ---
def gin_plan(cfg: ConvConfig, dtype=jnp.float32):
    p = {
        "eps": ParamSpec((), jnp.float32, (), init="zeros"),
        "mlp1": linear_plan(cfg.in_dim, cfg.out_dim, in_axis="embed",
                            out_axis="mlp", bias=True, dtype=dtype),
        "mlp2": linear_plan(cfg.out_dim, cfg.out_dim, in_axis="mlp",
                            out_axis="mlp", bias=True, dtype=dtype),
    }
    if cfg.edge_dim:
        p["w_edge"] = linear_plan(cfg.edge_dim, cfg.in_dim, in_axis=None,
                                  out_axis="embed", dtype=dtype)
    return p


def gin_apply(params, g, x, cfg: ConvConfig):
    """x' = MLP((1+eps) x_v + sum_u relu(x_u + W_e e_uv)) — edge features
    make this inexpressible as SpMM (paper Table II)."""
    src, dst = edge_endpoints(g)
    msg = _gather(x, src)
    if "w_edge" in params:
        msg = jax.nn.relu(msg + linear(params["w_edge"], g["edge_feat"]))
    aggr = agg_mod.segment_aggregate("sum", msg, dst, x.shape[0],
                                     g["valid_e"])
    h = (1.0 + params["eps"]) * x + aggr.astype(x.dtype)
    h = act(cfg.activation)(linear(params["mlp1"], h))
    return linear(params["mlp2"], h)


# ---------------------------------------------------------------- PNA ---
def pna_plan(cfg: ConvConfig, dtype=jnp.float32):
    tower_in = cfg.in_dim * len(PNA_AGGS) * len(PNA_SCALERS)
    p = {
        "pre": linear_plan(2 * cfg.in_dim + cfg.edge_dim, cfg.in_dim,
                           in_axis="embed", out_axis="mlp", bias=True,
                           dtype=dtype),
        "post": linear_plan(tower_in + cfg.in_dim, cfg.out_dim,
                            in_axis="embed", out_axis="mlp", bias=True,
                            dtype=dtype),
    }
    return p


def pna_apply(params, g, x, cfg: ConvConfig):
    """Principal Neighbourhood Aggregation: message MLP phi(x_v, x_u, e),
    4 aggregators x 3 degree scalers, then gamma on [x_v ; towers]."""
    src, dst = edge_endpoints(g)
    n = x.shape[0]
    h_src = _gather(x, src)
    h_dst = _gather(x, dst)
    feats = [h_dst, h_src]
    if cfg.edge_dim:
        feats.append(g["edge_feat"].astype(x.dtype))
    msg = act(cfg.activation)(
        linear(params["pre"], jnp.concatenate(feats, axis=-1)))
    towers = [agg_mod.segment_aggregate(a, msg, dst, n, g["valid_e"])
              for a in PNA_AGGS]
    deg = jnp.maximum(g["in_deg"], 1.0)
    logd = jnp.log(deg + 1.0)[:, None]
    scaled = []
    for t in towers:
        scaled += [t, t * (logd / cfg.delta), t * (cfg.delta / logd)]
    out = jnp.concatenate([x.astype(jnp.float32)] + scaled, axis=-1)
    return linear(params["post"], out.astype(x.dtype))


PLANS = {"gcn": gcn_plan, "sage": sage_plan, "gin": gin_plan,
         "pna": pna_plan}
APPLIES = {"gcn": gcn_apply, "sage": sage_apply, "gin": gin_apply,
           "pna": pna_apply}


def conv_plan(cfg: ConvConfig, dtype=jnp.float32):
    return PLANS[cfg.conv](cfg, dtype)


def conv_apply(params, g, x, cfg: ConvConfig):
    return APPLIES[cfg.conv](params, g, x, cfg)
