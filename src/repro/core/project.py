"""Project — GNNBuilder's push-button accelerator-generation workflow
(paper §III, Listing 1), retargeted from Vitis HLS to XLA/TPU.

Stage mapping (DESIGN.md §2):
  gen_hw_model()             -> build + lower the specialized jitted
                                inference program (HLS codegen analogue)
  gen_testbench()            -> export dataset + float reference outputs
  build_and_run_testbench()  -> run the program over the dataset, report
                                MAE (fixed vs float) + measured runtime;
                                also drains the packed GraphBatch path
                                and reports throughput in graphs/s
  run_synthesis()            -> compile, then emit the synthesis report:
                                roofline latency, FLOPs, HBM/VMEM bytes
                                (the Vitis latency/BRAM report analogue),
                                plus the packed-batch program's modeled
                                graphs/s under the node/edge budget
All artifacts land in ``build_dir`` (config.json, report.json, HLO text),
the analogue of the HLS project directory.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import convs as Cv
from repro.core import gnn_model as G
from repro.core import quantization as Q
from repro.data import pipeline as data_mod
from repro.nn import param as prm


@dataclasses.dataclass(frozen=True)
class TPUTarget:
    """Hardware constants (v5e) — the ``fpga_part`` analogue."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16
    hbm_bw: float = 819e9            # B/s
    link_bw: float = 50e9            # B/s per ICI link
    hbm_bytes: float = 16e9
    vmem_bytes: float = 128 * 2**20  # VMEM per core
    # fixed per-grid-step cost of a Pallas kernel invocation (dispatch +
    # block DMA setup) — the modeled quantity that makes tile-size knobs
    # observable to the DSE objective
    kernel_step_overhead: float = 100e-9

    def roofline_latency(self, flops: float, bytes_: float,
                         coll_bytes: float = 0.0) -> float:
        return max(flops / self.peak_flops, bytes_ / self.hbm_bw,
                   coll_bytes / self.link_bw)


class Project:
    def __init__(self, name: str, model_cfg: G.GNNModelConfig, task: str,
                 build_dir: str, dataset_cfg=None, max_nodes: int = 600,
                 max_edges: int = 600, num_nodes_guess: float = 18,
                 num_edges_guess: float = 38, degree_guess: float = 2.1,
                 float_or_fixed: str = "float", fpx: Q.FPX = Q.FPX(32, 16),
                 target: TPUTarget = TPUTarget(), n_jobs: int = 1,
                 seed: int = 0, batch_graphs: int = 32,
                 node_budget: int | None = None,
                 edge_budget: int | None = None,
                 edge_block: int = 128, node_block: int = 128,
                 agg_backend: str = "xla", dataflow: str | None = None,
                 precision=None, num_shards: int = 1,
                 gather_mode: str = "dma", fusion_depth: int = 1,
                 partition: int = 1):
        self.name = name
        # dataflow override + dataset degree flow into the per-layer
        # transform/aggregate planner (convs.resolve_dataflow);
        # precision (a name from quantization.PRECISIONS or a resolved
        # PrecisionPolicy) selects the per-layer datapath width
        cfg_updates = {"avg_degree": float(degree_guess)}
        if dataflow is not None:
            cfg_updates["gnn_dataflow"] = dataflow
        if isinstance(precision, str):
            cfg_updates["gnn_precision"] = precision
        self.cfg = dataclasses.replace(model_cfg, **cfg_updates)
        # resolved once per project; build_and_run_testbench max-abs
        # calibrates int8 grids on the testbench graphs before running
        self.policy = G.resolve_policy(
            self.cfg, precision if not isinstance(precision, str) else None)
        self.task = task
        self.build_dir = build_dir
        self.dataset_cfg = dataset_cfg or data_mod.GraphDataConfig(
            max_nodes=max_nodes, max_edges=max_edges,
            node_feat_dim=model_cfg.graph_input_feature_dim,
            edge_feat_dim=model_cfg.graph_input_edge_dim)
        self.max_nodes = max_nodes
        self.max_edges = max_edges
        self.num_nodes_guess = num_nodes_guess
        self.num_edges_guess = num_edges_guess
        self.degree_guess = degree_guess
        self.float_or_fixed = float_or_fixed
        self.fpx = fpx
        self.target = target
        self.seed = seed
        # packed GraphBatch execution budgets (DESIGN_BATCHING.md): the
        # flat buffers hold ~batch_graphs average graphs with 1.5x slack,
        # instead of batch_graphs * max_nodes worst-case padding.
        self.batch_graphs = batch_graphs
        self.node_budget = node_budget or data_mod.size_budget(
            batch_graphs, num_nodes_guess)
        self.edge_budget = edge_budget or data_mod.size_budget(
            batch_graphs, num_edges_guess)
        # segment-aggregation kernel tile sizes (DSE knobs, mirroring the
        # paper's parallelization factors) + backend selection
        self.edge_block = edge_block
        self.node_block = node_block
        self.agg_backend = agg_backend
        # gather kernel generation (aggregations.GATHER_MODES): "dma" =
        # the one-hot-free v2 kernel, "onehot" = the legacy contraction
        from repro.core.aggregations import GATHER_MODES
        if gather_mode not in GATHER_MODES:
            raise ValueError(f"gather_mode must be one of {GATHER_MODES}, "
                             f"got {gather_mode!r}")
        self.gather_mode = gather_mode
        # multi-layer VMEM residency: fusion_depth > 1 asks for the
        # resident conv stack; convs.residency_plan decides legality
        # against the target's VMEM at gen_hw_model time
        if fusion_depth < 1:
            raise ValueError(f"fusion_depth must be >= 1, "
                             f"got {fusion_depth}")
        self.fusion_depth = fusion_depth
        self.residency = None        # ResidencyPlan, set by gen_hw_model
        self.residency_engaged = False
        # data-parallel sharding: >1 splits each testbench/serving wave
        # into per-device packed shards over a ("data",) mesh, the
        # budgets above staying *per-shard* (graph-level partitioning —
        # the parallelization-factor knob one level above the kernels)
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = num_shards
        # intra-graph partitioning: >1 models serving ONE giant graph
        # split by edge cut across `partition` devices, each running the
        # per-shard packed program over its subgraph with per-layer halo
        # exchange (pipeline.partition_graph / apply_packed_partitioned).
        # Orthogonal to num_shards, which replicates whole graphs.
        if partition < 1:
            raise ValueError(f"partition must be >= 1, got {partition}")
        self.partition = partition
        self._fn = None
        self._fn_packed = None
        self._compiled = None
        self.params = None
        os.makedirs(build_dir, exist_ok=True)

    # ------------------------------------------------------- generation --
    def init_params(self, key=None):
        plan = G.model_plan(self.cfg)
        self.params = prm.materialize(
            plan, key if key is not None else jax.random.key(self.seed))
        return self.params

    def gen_hw_model(self):
        """Build the specialized inference program (codegen analogue)."""
        cfg = self.cfg
        quant = self.fpx if self.float_or_fixed == "fixed" else None
        backend = self.agg_backend

        def with_backend(apply_fn):
            # trace-time scope: segment_aggregate reads the process
            # default while the jitted program is being traced, so the
            # project's backend + tile choice is baked into its programs
            # without leaking to other projects in the same process
            def fn(params, batch):
                from repro.core import aggregations as agg_mod
                with agg_mod.backend_scope(backend, self.edge_block,
                                           self.node_block,
                                           gather_mode=self.gather_mode):
                    return apply_fn(params, batch)
            return fn

        policy = self.policy
        # multi-layer VMEM residency: the planner's budget rule decides
        # legality; the resident program additionally requires the Pallas
        # backend (it IS a Pallas kernel) and no legacy quant hook
        self.residency = Cv.residency_plan(
            [(cfg.conv_cfg(i).in_dim, cfg.conv_cfg(i).out_dim)
             for i in range(cfg.gnn_num_layers)],
            self.node_budget, cfg.gnn_conv, self.fusion_depth,
            quantized=not policy.is_fp32, edge_block=self.edge_block,
            vmem_bytes=int(self.target.vmem_bytes))
        resident = (self.residency.legal and self.fusion_depth > 1
                    and backend == "pallas" and quant is None)
        self.residency_engaged = resident
        self._fn = jax.jit(with_backend(
            lambda p, el: G.apply(p, cfg, el, quant, policy)))
        if resident:
            depth = self.residency.depth
            self._fn_packed = jax.jit(with_backend(
                lambda p, b: G.apply_packed_resident(
                    p, cfg, b, quant, policy, fusion_depth=depth,
                    edge_block=self.edge_block)))
        else:
            self._fn_packed = jax.jit(with_backend(
                lambda p, b: G.apply_packed(p, cfg, b, quant, policy)))
        with open(os.path.join(self.build_dir, "config.json"), "w") as f:
            json.dump({"name": self.name,
                       "model": dataclasses.asdict(cfg),
                       "quant": str(self.fpx),
                       "float_or_fixed": self.float_or_fixed,
                       # the resolved (possibly calibrated) per-layer
                       # precision policy this project's programs bake in
                       "precision": policy.describe(),
                       "max_nodes": self.max_nodes,
                       "max_edges": self.max_edges,
                       "batch_graphs": self.batch_graphs,
                       "node_budget": self.node_budget,
                       "edge_budget": self.edge_budget,
                       "edge_block": self.edge_block,
                       "node_block": self.node_block,
                       "agg_backend": self.agg_backend,
                       "gather_mode": self.gather_mode,
                       "fusion_depth": self.fusion_depth,
                       # the planner's verdict + whether the resident
                       # packed program actually engaged (it also needs
                       # the pallas backend and no legacy quant hook)
                       "residency": dataclasses.asdict(self.residency),
                       "residency_engaged": resident,
                       "num_shards": self.num_shards,
                       "partition": self.partition,
                       "dataflow": cfg.gnn_dataflow,
                       "dataflow_per_layer": [
                           Cv.resolve_dataflow(cfg.conv_cfg(i))
                           for i in range(cfg.gnn_num_layers)]},
                      f, indent=1, default=str)
        return self._fn

    def _abstract_graph(self):
        n, e = self.max_nodes, self.max_edges
        c = self.dataset_cfg
        sds = jax.ShapeDtypeStruct
        return {"node_feat": sds((n, c.node_feat_dim), jnp.float32),
                "edge_index": sds((e, 2), jnp.int32),
                "edge_feat": sds((e, c.edge_feat_dim), jnp.float32),
                "num_nodes": sds((), jnp.int32)}

    def _abstract_packed(self):
        nb, eb, gm = self.node_budget, self.edge_budget, self.batch_graphs
        c = self.dataset_cfg
        sds = jax.ShapeDtypeStruct
        return {"node_feat": sds((nb, c.node_feat_dim), jnp.float32),
                "node_graph_id": sds((nb,), jnp.int32),
                "edge_index": sds((eb, 2), jnp.int32),
                "edge_feat": sds((eb, c.edge_feat_dim), jnp.float32),
                "edge_graph_id": sds((eb,), jnp.int32),
                "graph_valid": sds((gm,), jnp.bool_),
                "graph_num_nodes": sds((gm,), jnp.int32),
                "num_graphs": sds((), jnp.int32)}

    _packed_to_device = staticmethod(G.packed_to_device)

    # -------------------------------------------------------- testbench --
    def gen_testbench(self, num_graphs: int = 64):
        """Export dataset graphs + float32 reference outputs (the paper's
        binary testbench data)."""
        ds = [data_mod.make_graph(self.dataset_cfg, i)
              for i in range(num_graphs)]
        if self.params is None:
            self.init_params()
        # the reference is always the full-precision program: pin an
        # explicit fp32 policy so cfg.gnn_precision cannot leak into it
        fp32 = Q.resolve_policy("fp32", self.cfg.gnn_num_layers)
        ref_fn = jax.jit(lambda p, el: G.apply(p, self.cfg, el, None, fp32))
        refs = [np.asarray(ref_fn(self.params, self._graph_to_el(g)))
                for g in ds]
        np.savez(os.path.join(self.build_dir, "testbench.npz"),
                 refs=np.stack(refs), n=num_graphs)
        self._tb_graphs = ds
        self._tb_refs = refs
        return len(ds)

    @staticmethod
    def _graph_to_el(g: data_mod.Graph) -> dict:
        return {"node_feat": jnp.asarray(g.node_feat),
                "edge_index": jnp.asarray(g.edge_index),
                "edge_feat": jnp.asarray(g.edge_feat),
                "num_nodes": jnp.int32(g.num_nodes)}

    def calibrate(self, num_graphs: int = 8):
        """Max-abs-calibrate the project's int8 grids on a packed batch
        of testbench graphs, then regenerate the jitted programs (and
        config.json) with the calibrated policy. No-op for fp32/bf16."""
        if not self.policy.needs_calibration:
            return self.policy
        if self.params is None:
            self.init_params()
        graphs = getattr(self, "_tb_graphs", None) \
            or [data_mod.make_graph(self.dataset_cfg, i)
                for i in range(num_graphs)]
        batch, _ = data_mod.pack_graphs(
            graphs[:num_graphs], self.node_budget, self.edge_budget,
            self.batch_graphs)
        self.policy = G.calibrated_policy(
            self.params, self.cfg, self._packed_to_device(batch),
            self.policy)
        self.gen_hw_model()          # re-bake programs + config.json
        return self.policy

    def build_and_run_testbench(self, packed: bool = True) -> dict:
        """Run the generated program on every testbench graph; report MAE
        vs the float reference and the measured mean runtime. With
        ``packed`` (default) the same graphs are also drained through the
        packed GraphBatch program, reporting throughput in graphs/s next
        to the single-graph latency; ``num_shards > 1`` projects
        additionally drain per-device shard waves through the sharded
        SPMD program (``tb["sharded"]``, skipped with a note when the
        host has fewer devices than shards). Quantized projects (int8 policy or
        the legacy fixed path) also report quantization-error stats
        (mean/max/SQNR-dB, ``quantization.quant_error_stats``)."""
        if self.params is None:
            self.init_params()
        if self.policy.needs_calibration:
            self.calibrate()
        if self._fn is None:
            self.gen_hw_model()
        params = self.params
        if self.float_or_fixed == "fixed":
            params = Q.quantize_tree(params, self.fpx)
        maes, times, outs = [], [], []
        out = None
        for g, ref in zip(self._tb_graphs, self._tb_refs):
            el = self._graph_to_el(g)
            out = self._fn(params, el)
            jax.block_until_ready(out)
        for g, ref in zip(self._tb_graphs, self._tb_refs):
            el = self._graph_to_el(g)
            t0 = time.perf_counter()
            out = self._fn(params, el)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
            outs.append(np.asarray(out))
            maes.append(float(np.mean(np.abs(outs[-1] - ref))))
        tb = {"mae": float(np.mean(maes)),
              "mean_runtime_ms": float(np.mean(times) * 1e3),
              "p50_runtime_ms": float(np.median(times) * 1e3),
              "n_graphs": len(self._tb_graphs),
              "loop_graphs_per_s": 1.0 / max(float(np.mean(times)), 1e-12),
              "quant": str(self.fpx) if self.float_or_fixed == "fixed"
              else "float32",
              "precision": self.policy.name}
        # quant-error report next to the throughput numbers: output error
        # vs the float references, plus the weight-grid error of the
        # quantized formats (quant_error_stats reduces; callers don't)
        if not self.policy.is_fp32 or self.float_or_fixed == "fixed":
            tb["quant_error"] = {"output": Q.error_stats(
                np.stack(outs), np.stack(self._tb_refs))}
            if self.float_or_fixed == "fixed":
                leaves = np.concatenate(
                    [np.asarray(a).ravel() for a in
                     jax.tree_util.tree_leaves(self.params)])
                tb["quant_error"]["weights"] = Q.quant_error_stats(
                    leaves, self.fpx)
            elif any(lp.compute == "int8" for lp in self.policy.layers) \
                    or self.policy.head.compute == "int8":
                # the exact weight tensors the datapath quantizes, each
                # against its own calibrated grid: per-layer conv weights
                # + the head (skip projections stay fp32 in _backbone)
                orig = {f"c{i}": self.params["convs"][f"c{i}"]
                        for i in range(self.cfg.gnn_num_layers)}
                orig["mlp"] = self.params.get("mlp", {})
                cast = {f"c{i}": self.policy.layer(i).cast_params(
                    self.params["convs"][f"c{i}"])
                    for i in range(self.cfg.gnn_num_layers)}
                cast["mlp"] = self.policy.head.cast_params(orig["mlp"])
                flat = [np.concatenate(
                    [np.asarray(a).ravel() for a in
                     jax.tree_util.tree_leaves(t)]) for t in (cast, orig)]
                tb["quant_error"]["weights"] = Q.error_stats(*flat)
        if packed:
            tb["packed"] = self._run_packed_testbench(params)
            if self.num_shards > 1:
                tb["sharded"] = self._run_sharded_testbench(params)
        with open(os.path.join(self.build_dir, "tb_data.json"), "w") as f:
            json.dump(tb, f, indent=1)
        return tb

    def _run_packed_testbench(self, params) -> dict:
        """Drain the testbench graphs through the packed program and
        compare against the per-graph float references."""
        batches, dropped = data_mod.pack_dataset(
            self._tb_graphs, self.node_budget, self.edge_budget,
            self.batch_graphs)
        dev_batches = [self._packed_to_device(b) for b in batches]
        for b in dev_batches:                       # warmup / compile
            jax.block_until_ready(self._fn_packed(params, b))
        n_graphs = 0
        maes = []
        t0 = time.perf_counter()
        outs = []
        for b in dev_batches:
            outs.append(self._fn_packed(params, b))
        jax.block_until_ready(outs)
        total_s = time.perf_counter() - t0
        refs = iter(r for g, r in zip(self._tb_graphs, self._tb_refs)
                    if data_mod.graph_fits_budget(
                        g, self.node_budget, self.edge_budget))
        for b, out in zip(batches, outs):
            k = int(b["num_graphs"])
            out = np.asarray(out)
            if self.cfg.task == "graph":
                for i in range(k):
                    maes.append(float(np.mean(np.abs(out[i] - next(refs)))))
            else:    # node task: rows are packed node embeddings
                off = 0
                for i in range(k):
                    n = int(b["graph_num_nodes"][i])
                    ref = next(refs)[:n]
                    maes.append(float(np.mean(
                        np.abs(out[off:off + n] - ref))))
                    off += n
            n_graphs += k
        return {
            "mae": float(np.mean(maes)) if maes else float("nan"),
            "graphs_per_s": n_graphs / max(total_s, 1e-12),
            "mean_batch_ms": total_s / max(len(batches), 1) * 1e3,
            "n_batches": len(batches),
            "n_graphs": n_graphs,
            "n_dropped": len(dropped),
            "batch_graphs": self.batch_graphs,
            "node_budget": self.node_budget,
            "edge_budget": self.edge_budget,
        }

    def _run_sharded_testbench(self, params) -> dict:
        """Drain the testbench graphs through the data-parallel sharded
        program — one SPMD program, each device of the ("data",) mesh
        consuming its own packed shard — and report sharded graphs/s
        next to the single-device packed numbers, with MAE against the
        same per-graph float references (host order restored by
        gather_shard_outputs)."""
        if len(jax.devices()) < self.num_shards:
            return {"skipped": f"needs {self.num_shards} devices, have "
                               f"{len(jax.devices())} (set XLA_FLAGS="
                               "--xla_force_host_platform_device_count)",
                    "num_shards": self.num_shards}
        from repro.core import aggregations as agg_mod
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(self.num_shards)
        quant = self.fpx if self.float_or_fixed == "fixed" else None
        base = G.make_sharded_apply(self.cfg, mesh, quant, self.policy)

        def fn(p, b):
            # trace-time backend scope, as gen_hw_model bakes into the
            # single-device programs
            with agg_mod.backend_scope(self.agg_backend, self.edge_block,
                                       self.node_block,
                                       gather_mode=self.gather_mode):
                return base(p, b)

        waves, dropped = data_mod.pack_dataset(
            self._tb_graphs, self.node_budget, self.edge_budget,
            self.batch_graphs, num_shards=self.num_shards)
        stacked = [G.stack_shards(w) for w in waves]
        for b in stacked:                           # warmup / compile
            jax.block_until_ready(fn(params, b))
        t0 = time.perf_counter()
        outs = [fn(params, b) for b in stacked]
        jax.block_until_ready(outs)
        total_s = time.perf_counter() - t0
        n_graphs = sum(w.n_graphs for w in waves)
        maes = []
        if self.cfg.task == "graph":
            refs = iter(r for g, r in zip(self._tb_graphs, self._tb_refs)
                        if data_mod.graph_fits_budget(
                            g, self.node_budget, self.edge_budget))
            for w, out in zip(waves, outs):
                host = data_mod.gather_shard_outputs(np.asarray(out),
                                                     w.index)
                for i in range(w.n_graphs):
                    maes.append(float(np.mean(np.abs(host[i]
                                                     - next(refs)))))
        return {
            "mae": float(np.mean(maes)) if maes else float("nan"),
            "graphs_per_s": n_graphs / max(total_s, 1e-12),
            "mean_wave_ms": total_s / max(len(waves), 1) * 1e3,
            "n_waves": len(waves),
            "n_graphs": n_graphs,
            "n_dropped": len(dropped),
            "num_shards": self.num_shards,
            "batch_graphs": self.batch_graphs,
            "node_budget": self.node_budget,
            "edge_budget": self.edge_budget,
        }

    # -------------------------------------------------------- synthesis --
    def run_synthesis(self, save_hlo: bool = False) -> dict:
        """Compile the program and emit the synthesis report: modeled
        roofline latency (Vitis latency analogue) + memory footprints
        (BRAM analogue). Also records compile wall-time — the quantity the
        paper's DSE beats by ~6 orders of magnitude."""
        if self._fn is None:
            self.gen_hw_model()
        plan = G.model_plan(self.cfg)
        t0 = time.time()
        lowered = self._fn.lower(prm.abstract(plan), self._abstract_graph())
        compiled = lowered.compile()
        compile_s = time.time() - t0
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        flops = float(cost.get("flops", 0.0))
        bytes_ = float(cost.get("bytes accessed", 0.0))
        try:
            ma = compiled.memory_analysis()
            temp = int(getattr(ma, "temp_size_in_bytes", 0))
            args = int(getattr(ma, "argument_size_in_bytes", 0))
        except Exception:
            temp = args = 0
        # utilization scaling with parallelism factors: a p=1 design issues
        # one MAC lane-group per cycle (the FPGA p=1 analogue — no MXU
        # tiling), p_h*p_out=128 fills the 128-lane systolic dimension.
        # This is the HLS II/unroll-factor effect mapped onto the MXU.
        p_eff = min(max(self.cfg.gnn_p_hidden * self.cfg.gnn_p_out, 1),
                    128) / 128
        eff_peak = self.target.peak_flops * p_eff
        # data-width scaling: cost_analysis sees the f32/fake-quant
        # emulation, so the modeled bytes shrink with the storage width —
        # the PrecisionPolicy byte width (bf16 = 2 B, int8 = 1 B), or the
        # legacy fixed-point width (<16,10> moves half of <32,16>).
        if not self.policy.is_fp32:
            width_scale = self.policy.compute_bytes / 4.0
        elif self.float_or_fixed == "fixed":
            width_scale = self.fpx.w / 32.0
        else:
            width_scale = 1.0
        bytes_eff = bytes_ * width_scale
        latency = max(flops / eff_peak, bytes_eff / self.target.hbm_bw)
        # packed-batch program: same model compiled over the GraphBatch
        # buffers; roofline latency amortizes over batch_graphs graphs.
        t0 = time.time()
        lowered_p = self._fn_packed.lower(prm.abstract(plan),
                                          self._abstract_packed())
        compiled_p = lowered_p.compile()
        compile_packed_s = time.time() - t0
        cost_p = compiled_p.cost_analysis()
        if isinstance(cost_p, (list, tuple)):
            cost_p = cost_p[0]
        flops_p = float(cost_p.get("flops", 0.0))
        bytes_p = float(cost_p.get("bytes accessed", 0.0)) * width_scale
        # aggregation-engine tile model: grid steps per conv layer, each
        # paying a fixed dispatch/DMA overhead — the II/unroll-factor
        # analogue for the tile knobs, and what the fitted DSE models
        # learn edge_block/node_block against (smaller tiles -> more
        # steps -> higher latency). The legacy one-hot kernel sweeps
        # ceil(E/EB) x ceil(N/NB) steps; the v2 DMA kernel's grid is
        # edge-tiles only (the node table is VMEM-resident).
        grid_steps = -(-self.edge_budget // self.edge_block)
        if self.gather_mode == "onehot":
            grid_steps *= -(-self.node_budget // self.node_block)
        agg_overhead_s = (self.cfg.gnn_num_layers * grid_steps
                          * self.target.kernel_step_overhead)
        # gather-stage compute honesty: XLA's cost analysis prices the
        # program it compiled, not the Pallas kernel the pallas backend
        # dispatches at run time — and the legacy one-hot kernel's dense
        # contractions are compute-bound by orders of magnitude. Fold
        # the modeled gather FLOPs (convs.gather_compute_flops) into the
        # roofline so a one-hot design can no longer "win" on modeled
        # bytes while losing 40x on the clock.
        gather_flops = 0.0
        if self.agg_backend == "pallas":
            feat = max(self.cfg.gnn_hidden_dim,
                       self.cfg.graph_input_feature_dim)
            gather_flops = self.cfg.gnn_num_layers \
                * Cv.gather_compute_flops(self.node_budget,
                                          self.edge_budget, feat,
                                          self.gather_mode,
                                          self.node_block)
        latency_p = max((flops_p + gather_flops) / eff_peak,
                        bytes_p / self.target.hbm_bw) + agg_overhead_s
        packed = {
            "latency_s": latency_p,
            "precision": self.policy.name,
            "compute_bytes": self.policy.compute_bytes,
            "agg_grid_steps": grid_steps,
            "agg_overhead_s": agg_overhead_s,
            "gather_mode": self.gather_mode,
            "gather_flops": gather_flops,
            "fusion_depth": self.fusion_depth,
            "residency_engaged": bool(
                getattr(self, "residency_engaged", False)),
            "edge_block": self.edge_block,
            "node_block": self.node_block,
            "flops": flops_p,
            "bytes_accessed": bytes_p,
            "batch_graphs": self.batch_graphs,
            "node_budget": self.node_budget,
            "edge_budget": self.edge_budget,
            "graphs_per_s": self.batch_graphs / max(latency_p, 1e-18),
            "per_graph_latency_s": latency_p / max(self.batch_graphs, 1),
            "compile_s": compile_packed_s,
        }
        # data-parallel sharded scaling model: every device runs the
        # *same* per-shard program concurrently (params replicated, no
        # inter-device traffic during the layer stack), so the wave
        # latency is the per-shard latency plus the host gather of the
        # per-device outputs over ICI — near-linear in num_shards, and
        # what benchmarks/sharded_throughput.py gates against.
        if self.cfg.task == "graph":
            out_vals = self.batch_graphs * (self.cfg.mlp_head.out_dim
                                            if self.cfg.mlp_head else 1)
        else:
            out_vals = self.node_budget * self.cfg.gnn_output_dim
        gather_bytes = 0.0 if self.num_shards == 1 \
            else self.num_shards * out_vals * 4.0
        latency_sh = latency_p + gather_bytes / self.target.link_bw
        wave_graphs = self.num_shards * self.batch_graphs
        packed["sharded"] = {
            "num_shards": self.num_shards,
            "latency_s": latency_sh,
            "gather_bytes": gather_bytes,
            "wave_graphs": wave_graphs,
            "graphs_per_s": wave_graphs / max(latency_sh, 1e-18),
            "scaling_efficiency": (wave_graphs / max(latency_sh, 1e-18))
            / max(self.num_shards * packed["graphs_per_s"], 1e-18),
        }
        # intra-graph partitioned model (giant-graph inference): one
        # graph ~partition x the per-device budget, split by edge cut;
        # every device runs the per-shard program concurrently and each
        # layer boundary all-gathers the halo rows over ICI. The modeled
        # cut is the balanced worst case — (P-1)/P of the per-device
        # edge budget crosses parts — priced by convs.halo_comm_bytes at
        # the policy's storage width. The padded-oracle baseline the
        # partitioned program retires pays the full P-times-larger
        # buffers instead (latency scales ~P with no comm term).
        feat_dim = max(self.cfg.gnn_hidden_dim,
                       self.cfg.graph_input_feature_dim)
        cut_model = (self.partition - 1) / self.partition \
            * self.edge_budget
        halo_bytes = Cv.halo_comm_bytes(cut_model, feat_dim,
                                        self.policy.compute_bytes,
                                        self.cfg.gnn_num_layers)
        comm_s = halo_bytes / self.target.link_bw
        latency_pt = latency_p + comm_s
        packed["partitioned"] = {
            "partition": self.partition,
            "modeled_cut_edges": cut_model,
            "halo_comm_bytes": halo_bytes,
            "comm_s": comm_s,
            "latency_s": latency_pt,
            # one giant graph per partitioned launch: this is the rate
            # at which oversize requests drain, vs the padded oracle's
            # ~partition-times-larger single-device program
            "oversize_graphs_per_s": 1.0 / max(latency_pt, 1e-18),
            "padded_oracle_latency_s": latency_p * self.partition,
        }
        report = {
            "packed": packed,
            "latency_s": latency,
            "latency_ms": latency * 1e3,
            "flops": flops,
            "bytes_accessed": bytes_,
            "temp_bytes": temp,
            "arg_bytes": args,
            "hbm_total_bytes": temp + args,
            "fits_hbm": (temp + args) < self.target.hbm_bytes,
            "compile_s": compile_s,
            "target": self.target.name,
            "precision": self.policy.name,
        }
        self._compiled = compiled
        if save_hlo:
            with open(os.path.join(self.build_dir, "kernel.hlo"), "w") as f:
                f.write(compiled.as_text())
        with open(os.path.join(self.build_dir, "report.json"), "w") as f:
            json.dump(report, f, indent=1)
        return report

    # paper-API alias
    run_vitis_hls_synthesis = run_synthesis
