"""Direct-fit hardware performance models (paper §VII-B, Fig. 4).

Random-forest regressors (implemented in NumPy — no sklearn available)
fitted on a database of synthesized design points, predicting latency and
memory ("BRAM" analogue) from the configuration feature vector. The paper
uses 10-estimator forests over 400 sampled designs with 5-fold CV MAPE;
we reproduce the exact protocol against XLA-compiled design points.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import convs as Cv
from repro.core.convs import ConvConfig, halo_comm_bytes, resolve_dataflow
from repro.core.quantization import BYTE_WIDTHS


# -------------------------------------------------------- decision tree --
class _Node:
    __slots__ = ("feat", "thresh", "left", "right", "value")

    def __init__(self, value=None):
        self.feat = -1
        self.thresh = 0.0
        self.left = None
        self.right = None
        self.value = value


class DecisionTreeRegressor:
    """CART with variance-reduction splits."""

    def __init__(self, max_depth: int = 12, min_samples_leaf: int = 2,
                 max_features: float | None = None, rng=None):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng(0)
        self.root = None

    def fit(self, x: np.ndarray, y: np.ndarray):
        self.root = self._build(np.asarray(x, float), np.asarray(y, float),
                                0)
        return self

    def _best_split(self, x, y):
        """Vectorized SSE scan: prefix sums over the sorted targets give
        every split position's left/right SSE in one NumPy expression
        (same splits and trees as the scalar loop it replaced)."""
        n, d = x.shape
        feats = np.arange(d)
        if self.max_features:
            k = max(1, int(d * self.max_features))
            feats = self.rng.choice(d, size=k, replace=False)
        best = (None, None, np.inf)
        ml = self.min_samples_leaf
        idx = np.arange(ml, n - ml + 1)
        if len(idx) == 0:
            return best
        for f in feats:
            order = np.argsort(x[:, f], kind="stable")
            xs, ys = x[order, f], y[order]
            csum = np.cumsum(ys)
            csq = np.cumsum(ys * ys)
            total, total_sq = csum[-1], csq[-1]
            sl, sl2 = csum[idx - 1], csq[idx - 1]
            nl, nr = idx, n - idx
            sse = (sl2 - sl * sl / nl) \
                + ((total_sq - sl2) - (total - sl) ** 2 / nr)
            # splits between equal feature values are not realizable
            sse = np.where(xs[idx - 1] == xs[idx], np.inf, sse)
            j = int(np.argmin(sse))
            if sse[j] < best[2]:
                i = idx[j]
                best = (int(f), (xs[i - 1] + xs[i]) / 2, float(sse[j]))
        return best

    def _build(self, x, y, depth):
        if depth >= self.max_depth or len(y) < 2 * self.min_samples_leaf \
                or np.all(y == y[0]):
            return _Node(value=float(np.mean(y)))
        f, t, _ = self._best_split(x, y)
        if f is None:
            return _Node(value=float(np.mean(y)))
        mask = x[:, f] <= t
        if mask.all() or not mask.any():
            return _Node(value=float(np.mean(y)))
        node = _Node()
        node.feat, node.thresh = int(f), float(t)
        node.left = self._build(x[mask], y[mask], depth + 1)
        node.right = self._build(x[~mask], y[~mask], depth + 1)
        return node

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, float)
        out = np.empty(len(x))
        for i, row in enumerate(x):
            node = self.root
            while node.value is None:
                node = node.left if row[node.feat] <= node.thresh \
                    else node.right
            out[i] = node.value
        return out


class RandomForestRegressor:
    """Bootstrap ensemble of CARTs (paper: 10 estimators)."""

    def __init__(self, n_estimators: int = 10, max_depth: int = 12,
                 min_samples_leaf: int = 2, max_features: float = 0.8,
                 seed: int = 0):
        self.n_estimators = n_estimators
        self.kw = dict(max_depth=max_depth,
                       min_samples_leaf=min_samples_leaf,
                       max_features=max_features)
        self.seed = seed
        self.trees: list = []

    def fit(self, x: np.ndarray, y: np.ndarray):
        x, y = np.asarray(x, float), np.asarray(y, float)
        rng = np.random.default_rng(self.seed)
        self.trees = []
        for i in range(self.n_estimators):
            idx = rng.integers(0, len(y), size=len(y))
            t = DecisionTreeRegressor(
                rng=np.random.default_rng(self.seed + 1000 + i), **self.kw)
            t.fit(x[idx], y[idx])
            self.trees.append(t)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.mean([t.predict(x) for t in self.trees], axis=0)


# -------------------------------------------------------------- metrics --
def mape(y_true, y_pred) -> float:
    y_true, y_pred = np.asarray(y_true, float), np.asarray(y_pred, float)
    denom = np.maximum(np.abs(y_true), 1e-12)
    return float(np.mean(np.abs(y_true - y_pred) / denom) * 100.0)


def kfold_cv_mape(x, y, k: int = 5, seed: int = 0, **forest_kw) -> float:
    """Paper protocol: 5-fold CV, averaged test MAPE."""
    x, y = np.asarray(x, float), np.asarray(y, float)
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(y))
    folds = np.array_split(idx, k)
    scores = []
    for i in range(k):
        test = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        model = RandomForestRegressor(seed=seed + i, **forest_kw)
        model.fit(x[train], y[train])
        scores.append(mape(y[test], model.predict(x[test])))
    return float(np.mean(scores))


# ------------------------------------------------------------- features --
def _dse_convs() -> list:
    """Conv one-hot axis, derived from the conv registry: the convs the
    DSE enumerates, in registration order. A legacy database recorded
    before a conv existed featurizes with a zero in the new slot — its
    designs simply never carried that name (e.g. pre-gat rows are
    non-attention by construction; docs/DSE.md legacy-defaults table)."""
    return [n for n in Cv.CONV_TYPES if Cv.conv_spec(n).dse]


_TAIL_FEATURE_NAMES = [
    "gnn_hidden_dim", "gnn_out_dim", "gnn_layers", "skip",
    "mlp_hidden_dim", "mlp_layers",
    "gnn_p_in", "gnn_p_hidden", "gnn_p_out",
    "mlp_p_in", "mlp_p_hidden", "mlp_p_out",
    "in_dim", "edge_dim", "avg_nodes", "avg_edges", "avg_degree",
    "fpx_bits",
    # packed GraphBatch budget axis (predicting packed throughput)
    "batch_graphs", "node_budget", "edge_budget",
    # segment-aggregation kernel tile sizes (Pallas edge/node blocks)
    "edge_block", "node_block",
    # transform/aggregate reordering: the explicit setting (one-hot;
    # "auto" = both zero) plus the *resolved* aggregation width of the
    # final conv layer, so the forests price the edge-bandwidth cut
    "dataflow_aggregate_first", "dataflow_transform_first",
    "agg_width_last",
    # PrecisionPolicy axis: compute-dtype one-hot (fp32 = both zero) and
    # the storage bytes per value, so the forests price the bandwidth
    # cut of low-precision node/message tiles
    "precision_bf16", "precision_int8", "compute_bytes",
    # data-parallel sharding axis: num_shards one-hot (single-device =
    # all zero, the legacy-database default). The node/edge budgets
    # above are *per shard* — a sharded design replicates the same
    # buffers on every device — so the one-hot alone carries the
    # wave-throughput scaling signal
    "shards_2", "shards_4", "shards_8",
    # gather kernel generation + multi-layer VMEM residency: "dma" (the
    # one-hot-free v2 kernel) vs the legacy one-hot contraction, and the
    # layer-fusion depth of the resident conv stack. Legacy databases
    # predate both knobs and default to (onehot, depth 1) — exactly what
    # they executed with
    "gather_dma", "fusion_depth",
    # intra-graph partitioned inference: device count one oversize graph
    # is split across, plus the modeled per-layer halo exchange volume
    # (convs.halo_comm_bytes over the balanced worst-case cut at the
    # design's storage width). Legacy databases predate the knob and
    # featurize as unpartitioned (partition=1, zero comm bytes)
    "partition", "halo_comm_bytes",
]

FEATURE_NAMES: list = []


def _rebuild_feature_names():
    # in-place so ``from perf_model import FEATURE_NAMES`` aliases stay
    # live when a conv is (un)registered
    FEATURE_NAMES[:] = [f"conv_{c}" for c in _dse_convs()] \
        + _TAIL_FEATURE_NAMES


_rebuild_feature_names()
Cv.on_registry_change(_rebuild_feature_names)


def _resolved_agg_width(design: dict) -> float:
    """Aggregation width of the final conv layer after the dataflow
    planner runs — delegates to convs.resolve_dataflow so the feature
    can never desynchronize from the ordering a design executes with."""
    hid = design["gnn_hidden_dim"] if design["gnn_layers"] > 1 \
        else design["in_dim"]
    out = design["gnn_out_dim"]
    cc = ConvConfig(in_dim=hid, out_dim=out, conv=design["conv"],
                    dataflow=design.get("dataflow", "auto"),
                    avg_degree=float(design.get("avg_degree", 2.0)))
    return float(out if resolve_dataflow(cc) == "transform_first"
                 else hid)


def features(design: dict) -> np.ndarray:
    """Design-point dict (see dse.sample_design) -> feature vector.
    Batch-budget fields default to the single-graph setting, the
    precision axis defaults to fp32 (4 B/value), and the sharding axis
    defaults to one device (zero one-hot), so databases recorded before
    the packed-batch / precision / sharding refactors still
    featurize."""
    onehot = [1.0 if design["conv"] == c else 0.0 for c in _dse_convs()]
    return np.array(onehot + [
        design["gnn_hidden_dim"], design["gnn_out_dim"],
        design["gnn_layers"], float(design["skip"]),
        design["mlp_hidden_dim"], design["mlp_layers"],
        design["gnn_p_in"], design["gnn_p_hidden"], design["gnn_p_out"],
        design["mlp_p_in"], design["mlp_p_hidden"], design["mlp_p_out"],
        design["in_dim"], design["edge_dim"],
        design["avg_nodes"], design["avg_edges"], design["avg_degree"],
        design.get("fpx_bits", 32),
        design.get("batch_graphs", 1),
        design.get("node_budget", design["avg_nodes"]),
        design.get("edge_budget", design["avg_edges"]),
        design.get("edge_block", 128),
        design.get("node_block", 128),
        1.0 if design.get("dataflow") == "aggregate_first" else 0.0,
        1.0 if design.get("dataflow") == "transform_first" else 0.0,
        _resolved_agg_width(design),
        1.0 if design.get("precision", "fp32") == "bf16" else 0.0,
        1.0 if design.get("precision", "fp32") == "int8" else 0.0,
        float(BYTE_WIDTHS[design.get("precision", "fp32")]),
        1.0 if design.get("num_shards", 1) == 2 else 0.0,
        1.0 if design.get("num_shards", 1) == 4 else 0.0,
        1.0 if design.get("num_shards", 1) == 8 else 0.0,
        1.0 if design.get("gather_mode", "onehot") == "dma" else 0.0,
        float(design.get("fusion_depth", 1)),
        float(design.get("partition", 1)),
        _halo_comm_bytes(design),
    ], dtype=float)


def _halo_comm_bytes(design: dict) -> float:
    """Modeled partitioned-inference exchange volume for the feature
    vector: the balanced worst-case cut — (P-1)/P of the per-device edge
    budget — through convs.halo_comm_bytes at the design's storage
    width. Zero for unpartitioned designs, including every legacy
    database row."""
    p = int(design.get("partition", 1))
    if p <= 1:
        return 0.0
    cut = (p - 1) / p * float(design.get("edge_budget",
                                         design["avg_edges"]))
    width = float(BYTE_WIDTHS[design.get("precision", "fp32")])
    return halo_comm_bytes(cut, design["gnn_hidden_dim"], width,
                           design["gnn_layers"])
