"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/  arrays.npz (flattened pytree)  meta.json
Writes go to a temp dir + atomic rename, so a preempted save never corrupts
the latest checkpoint. ``save_async`` moves serialization off the step
path. On restore, arrays are re-placed under the *current* mesh's
shardings — a checkpoint taken on 512 devices restores on 8 (elastic
down-scale) or vice versa, because the on-disk format is topology-free.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(prefix + [str(k)], v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(prefix + [f"#{i}"], v)
        else:
            flat[SEP.join(prefix)] = node

    rec([], tree)
    return flat


def _unflatten_into(template, flat: dict):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(prefix + [str(k)], v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(prefix + [f"#{i}"], v)
                              for i, v in enumerate(node))
        key = SEP.join(prefix)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        return flat[key]

    return rec([], template)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save --
    def _write(self, step: int, host_tree: dict, meta: dict):
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **host_tree)
            meta = dict(meta, step=step, time=time.time())
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            final = os.path.join(self.dir, f"step_{step:09d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def save(self, step: int, tree, meta: dict | None = None):
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self._write(step, host, meta or {})

    def save_async(self, step: int, tree, meta: dict | None = None):
        """Device->host copy happens here; file I/O on a background thread."""
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, meta or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None) -> tuple:
        """Restore into the structure of ``template``. If ``shardings`` is
        given (pytree of NamedSharding matching template), arrays are placed
        sharded under the *current* mesh — the elastic-rescale path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, meta
