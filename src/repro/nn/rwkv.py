"""RWKV-6 ("Finch") attention-free mixer with data-dependent decay.

Time-mix uses the WKV6 recurrence over per-head (hd x hd) outer-product
state; train/prefill runs a chunked scan (sequential across chunks,
within-chunk recurrence unrolled via lax.scan over time) keeping state in
fp32. Decode is one recurrence step. Channel-mix is the RWKV squared-ReLU
FFN with token shift.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import linear, linear_plan
from repro.nn.param import ParamSpec
from repro.nn.attention import Constrain, NO_CONSTRAIN


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    head_dim: int = 64
    d_ff: int = 0              # channel-mix hidden (0 -> 3.5x d_model)
    decay_lora: int = 64

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_dim


def time_mix_plan(cfg: RWKVConfig, dtype=jnp.bfloat16):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "mu": ParamSpec((5, d), dtype, (None, "embed"), scale=0.02),
        "w_r": linear_plan(d, d, in_axis="embed", out_axis="heads",
                           dtype=dtype),
        "w_k": linear_plan(d, d, in_axis="embed", out_axis="heads",
                           dtype=dtype),
        "w_v": linear_plan(d, d, in_axis="embed", out_axis="heads",
                           dtype=dtype),
        "w_g": linear_plan(d, d, in_axis="embed", out_axis="heads",
                           dtype=dtype),
        # data-dependent decay: low-rank lora w = base + tanh(x A) B
        "decay_base": ParamSpec((d,), jnp.float32, ("embed",), init="zeros"),
        "decay_a": linear_plan(d, cfg.decay_lora, in_axis="embed",
                               out_axis=None, dtype=dtype),
        "decay_b": linear_plan(cfg.decay_lora, d, in_axis=None,
                               out_axis="heads", dtype=dtype),
        "bonus": ParamSpec((h, hd), jnp.float32, ("heads", None),
                           init="zeros"),
        "ln_x": {"scale": ParamSpec((d,), dtype, ("embed",), init="ones"),
                 "bias": ParamSpec((d,), dtype, ("embed",), init="zeros")},
        "w_o": linear_plan(d, d, in_axis="heads", out_axis="embed",
                           dtype=dtype),
    }


def channel_mix_plan(cfg: RWKVConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    ff = cfg.d_ff or int(3.5 * d)
    return {
        "mu": ParamSpec((2, d), dtype, (None, "embed"), scale=0.02),
        "w_k": linear_plan(d, ff, in_axis="embed", out_axis="mlp",
                           dtype=dtype),
        "w_v": linear_plan(ff, d, in_axis="mlp", out_axis="embed",
                           dtype=dtype),
        "w_r": linear_plan(d, d, in_axis="embed", out_axis="mlp",
                           dtype=dtype),
    }


def _token_shift(x, last):
    """shift right by one; ``last`` (B, d) is the final token of prev chunk."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _wkv_step(state, r, k, v, w, u):
    """state (B,H,hd,hd); r,k,v (B,H,hd); w decay (B,H,hd); u bonus (H,hd).

    out = r . (state + u * k^T v);  state' = diag(w) state + k^T v
    """
    kv = k[..., :, None] * v[..., None, :]            # (B,H,hd,hd)
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., :, None] * kv)
    state = w[..., :, None] * state + kv
    return state, out


def time_mix_forward(params, x, cfg: RWKVConfig, state=None, x_last=None,
                     constrain: Constrain = NO_CONSTRAIN):
    """x: (B, S, d). Returns (y, (state, last_token)).

    state: (B, H, hd, hd) fp32 WKV state carried across calls (chunked
    prefill / decode continuation).
    """
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    if x_last is None:
        x_last = jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, x_last)
    mu = params["mu"]
    mix = lambda i: x + (xs - x) * mu[i]
    r = linear(params["w_r"], mix(0)).reshape(b, s, h, hd)
    k = linear(params["w_k"], mix(1)).reshape(b, s, h, hd)
    v = linear(params["w_v"], mix(2)).reshape(b, s, h, hd)
    g = jax.nn.silu(linear(params["w_g"], mix(3)))
    dec = params["decay_base"] + linear(
        params["decay_b"], jnp.tanh(linear(params["decay_a"], mix(4)))
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(b, s, h, hd)   # data-dependent decay
    u = params["bonus"]

    def body(st, inp):
        rt, kt, vt, wt = inp
        st, out = _wkv_step(st, rt, kt, vt, wt, u)
        return st, out

    seq_first = lambda t: t.astype(jnp.float32).swapaxes(0, 1)
    state, outs = jax.lax.scan(
        body, state, (seq_first(r), seq_first(k), seq_first(v),
                      seq_first(w)))
    y = outs.swapaxes(0, 1).reshape(b, s, d)
    # group-norm per head (ln over hd), then gate and output-project
    yh = y.reshape(b, s, h, hd)
    mu_h = yh.mean(-1, keepdims=True)
    var_h = yh.var(-1, keepdims=True)
    yh = (yh - mu_h) * jax.lax.rsqrt(var_h + 64e-5)
    y = yh.reshape(b, s, d) * params["ln_x"]["scale"].astype(jnp.float32) \
        + params["ln_x"]["bias"].astype(jnp.float32)
    y = (y.astype(x.dtype) * g)
    y = constrain(y, ("batch", "seq", "embed"))
    return linear(params["w_o"], y), (state, x[:, -1])


def channel_mix_forward(params, x, x_last=None):
    b, s, d = x.shape
    if x_last is None:
        x_last = jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, x_last)
    mu = params["mu"]
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(linear(params["w_k"], xk)))
    return jax.nn.sigmoid(linear(params["w_r"], xr)) \
        * linear(params["w_v"], k), x[:, -1]
