"""Attention substrate: GQA (+RoPE, qk-norm), chunked online-softmax
attention, decode against seq-sharded KV caches, MLA (deepseek-v2) with
absorbed-matmul decode, and cross-attention (VLM / enc-dec).

TP note: on the fixed 16-way ``model`` axis, head counts that do not divide
16 are padded up (``num_heads_padded`` in the arch config) — the standard
Megatron/MaxText constraint; the FLOP overhead is charged honestly in the
roofline (it appears in HLO_FLOPs, not MODEL_FLOPS).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.nn.layers import linear, linear_plan, rmsnorm
from repro.nn.param import ParamSpec

Constrain = Callable  # (x, logical_axes) -> x
NO_CONSTRAIN: Constrain = lambda x, axes: x


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int              # logical head count (paper-exact)
    num_kv_heads: int
    head_dim: int
    num_heads_padded: int = 0   # 0 => same as num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    use_rope: bool = True
    chunk: int = 1024           # KV chunk for online-softmax attention

    @property
    def h(self) -> int:
        return self.num_heads_padded or self.num_heads


# ------------------------------------------------------------------ rope --
def rope(x, positions, theta: float):
    """Rotary embedding over the last dim. x: (..., S, D); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.arange(half, dtype=jnp.float32) / half
    inv = theta ** -freq                                   # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------- online-softmax chunked attn --
def online_attention(q, k, v, *, causal: bool, chunk: int,
                     q_positions=None, kv_positions=None, scale=None):
    """Memory-efficient attention: lax.scan over KV chunks, online softmax.

    q: (B, H, Sq, Dk); k: (B, H, Skv, Dk); v: (B, H, Skv, Dv).
    Positions enable causal masking when Sq != Skv (prefill continuation).
    Scores working set is bounded to (B, H, Sq, chunk).
    """
    b, h, sq, dk = q.shape
    skv, dv = k.shape[2], v.shape[-1]
    scale = scale if scale is not None else dk ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if kv_positions is None:
        kv_positions = jnp.arange(skv)
    chunk = min(chunk, skv)
    nc = -(-skv // chunk)
    pad = nc * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_positions = jnp.concatenate(
            [kv_positions, jnp.full((pad,), 2**30, kv_positions.dtype)])
    kc = k.reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    pc = kv_positions.reshape(nc, chunk)
    qf = q.astype(jnp.float32) * scale

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s = jnp.einsum("bhqd,bhcd->bhqc", qf, kb.astype(jnp.float32))
        mask = pb[None, None, None, :] <= 2**29          # padding mask
        if causal:
            mask = mask & (pb[None, None, None, :]
                           <= q_positions[None, None, :, None])
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bhcd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, dv), jnp.float32))
    # checkpoint the chunk body: masks/probabilities are recomputed in the
    # backward pass (flash-attention-style) instead of being stacked.
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init, (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ----------------------------------------------------------- GQA module --
def attn_plan(cfg: AttnConfig, dtype=jnp.bfloat16):
    d, h, kv, hd = cfg.d_model, cfg.h, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": linear_plan(d, h * hd, in_axis="embed", out_axis="heads",
                          dtype=dtype),
        "wk": linear_plan(d, kv * hd, in_axis="embed", out_axis="kv_flat",
                          dtype=dtype),
        "wv": linear_plan(d, kv * hd, in_axis="embed", out_axis="kv_flat",
                          dtype=dtype),
        "wo": linear_plan(h * hd, d, in_axis="heads", out_axis="embed",
                          dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": ParamSpec((hd,), dtype, (None,), init="ones")}
        p["k_norm"] = {"scale": ParamSpec((hd,), dtype, (None,), init="ones")}
    return p


def _qkv(params, x, cfg: AttnConfig, positions, constrain: Constrain):
    b, s, _ = x.shape
    h, kv, hd = cfg.h, cfg.num_kv_heads, cfg.head_dim
    q = linear(params["wq"], x).reshape(b, s, h, hd)
    k = linear(params["wk"], x).reshape(b, s, kv, hd)
    v = linear(params["wv"], x).reshape(b, s, kv, hd)
    q = constrain(q, ("batch", "mixer_seq", "heads", None))
    # kv heads (8) never divide the 16-way model axis: keep k/v replicated
    # (explicitly — otherwise GSPMD falls back to involuntary full remat
    # when resharding the flat kv projection into heads).
    k = constrain(k, ("batch", None, None, None))
    v = constrain(v, ("batch", None, None, None))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if cfg.use_rope:
        q = rope(q.transpose(0, 2, 1, 3), positions[:, None, :],
                 cfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope(k.transpose(0, 2, 1, 3), positions[:, None, :],
                 cfg.rope_theta).transpose(0, 2, 1, 3)
    return q, k, v


def _expand_kv(k, h: int, constrain: Constrain, batch_logical="batch"):
    """(B, S, KV, D) -> (B, H, S, D), sharded to match q heads."""
    b, s, kvh, hd = k.shape
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3)
    return constrain(k, (batch_logical, "heads", None, None))


def attn_forward(params, x, cfg: AttnConfig, positions,
                 constrain: Constrain = NO_CONSTRAIN):
    """Full-sequence attention (train / prefill). Returns (y, (k, v) cache)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, cfg, positions, constrain)
    qh = q.transpose(0, 2, 1, 3)
    kh = _expand_kv(k, cfg.h, constrain)
    vh = _expand_kv(v, cfg.h, constrain)
    out = online_attention(qh, kh, vh, causal=cfg.causal, chunk=cfg.chunk,
                           q_positions=positions[0], kv_positions=positions[0])
    y = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.h * cfg.head_dim)
    return linear(params["wo"], y), (k, v)


def attn_decode(params, x, k_cache, v_cache, pos, cfg: AttnConfig,
                constrain: Constrain = NO_CONSTRAIN, seq_axis="kv_seq"):
    """One-token decode. x: (B, 1, d); caches (B, S, KV, D), seq-sharded.

    pos: scalar int32 — current position (tokens [0, pos) are valid).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    # decode is cache-bandwidth-bound: keep q heads replicated so the GQA
    # group reshape stays local; parallelism comes from the seq-sharded cache.
    decode_constrain: Constrain = lambda t, axes: constrain(
        t, tuple(None if a == "heads" else a for a in axes))
    q, k, v = _qkv(params, x, cfg, positions, decode_constrain)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    k_cache = constrain(k_cache, ("batch", seq_axis, None, None))
    v_cache = constrain(v_cache, ("batch", seq_axis, None, None))
    s = k_cache.shape[1]
    rep = cfg.h // cfg.num_kv_heads
    # scores over the seq-sharded cache: softmax/reduce lower to tiny
    # all-reduces over the `model` axis (flash-decode semantics via GSPMD).
    qh = q.reshape(b, cfg.num_kv_heads, rep, cfg.head_dim)
    scores = jnp.einsum("bkrd,bskd->bkrs", qh.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * cfg.head_dim ** -0.5
    valid = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", w, v_cache.astype(jnp.float32))
    y = out.reshape(b, 1, cfg.h * cfg.head_dim).astype(x.dtype)
    return linear(params["wo"], y), k_cache, v_cache


# ------------------------------------------------------ cross-attention --
def xattn_plan(cfg: AttnConfig, mem_dim: int | None = None,
               dtype=jnp.bfloat16):
    d, h, kv, hd = cfg.d_model, cfg.h, cfg.num_kv_heads, cfg.head_dim
    mem = mem_dim or d
    return {
        "wq": linear_plan(d, h * hd, in_axis="embed", out_axis="heads",
                          dtype=dtype),
        "wk": linear_plan(mem, kv * hd, in_axis="embed", out_axis="kv_flat",
                          dtype=dtype),
        "wv": linear_plan(mem, kv * hd, in_axis="embed", out_axis="kv_flat",
                          dtype=dtype),
        "wo": linear_plan(h * hd, d, in_axis="heads", out_axis="embed",
                          dtype=dtype),
        "gate": ParamSpec((1,), dtype, (None,), init="zeros"),
    }


def xattn_kv(params, mem, cfg: AttnConfig):
    b, sm, _ = mem.shape
    k = linear(params["wk"], mem).reshape(b, sm, cfg.num_kv_heads,
                                          cfg.head_dim)
    v = linear(params["wv"], mem).reshape(b, sm, cfg.num_kv_heads,
                                          cfg.head_dim)
    return k, v


def xattn_forward(params, x, kv, cfg: AttnConfig,
                  constrain: Constrain = NO_CONSTRAIN):
    """Cross-attention; kv = (k, v) precomputed from memory (image/encoder)."""
    b, s, _ = x.shape
    k, v = kv
    q = linear(params["wq"], x).reshape(b, s, cfg.h, cfg.head_dim)
    q = constrain(q, ("batch", "mixer_seq", "heads", None)).transpose(0, 2, 1, 3)
    kh = _expand_kv(k, cfg.h, constrain)
    vh = _expand_kv(v, cfg.h, constrain)
    out = online_attention(q, kh, vh, causal=False, chunk=cfg.chunk)
    y = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.h * cfg.head_dim)
    return jnp.tanh(params["gate"].astype(jnp.float32)).astype(x.dtype) \
        * linear(params["wo"], y)


# -------------------------------------------------------------- MLA (v2) --
@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    num_heads: int
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    chunk: int = 1024

    @property
    def cache_dim(self) -> int:
        return self.kv_lora + self.qk_rope_dim


def mla_plan(cfg: MLAConfig, dtype=jnp.bfloat16):
    d, h = cfg.d_model, cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": linear_plan(d, h * qd, in_axis="embed", out_axis="heads",
                          dtype=dtype),
        "w_dkv": linear_plan(d, cfg.kv_lora, in_axis="embed",
                             out_axis="kv_lora", dtype=dtype),
        "w_kr": linear_plan(d, cfg.qk_rope_dim, in_axis="embed",
                            out_axis=None, dtype=dtype),
        "kv_norm": {"scale": ParamSpec((cfg.kv_lora,), dtype, (None,),
                                       init="ones")},
        "w_uk": ParamSpec((cfg.kv_lora, h, cfg.qk_nope_dim), dtype,
                          ("kv_lora", "heads", None)),
        "w_uv": ParamSpec((cfg.kv_lora, h, cfg.v_head_dim), dtype,
                          ("kv_lora", "heads", None)),
        "wo": linear_plan(h * cfg.v_head_dim, d, in_axis="heads",
                          out_axis="embed", dtype=dtype),
    }


def _mla_q(params, x, cfg: MLAConfig, positions, constrain: Constrain):
    b, s, _ = x.shape
    h = cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = linear(params["wq"], x).reshape(b, s, h, qd)
    q = constrain(q, ("batch", "mixer_seq", "heads", None))
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = rope(q_rope.transpose(0, 2, 1, 3), positions[:, None, :],
                  cfg.rope_theta).transpose(0, 2, 1, 3)
    return q_nope, q_rope


def mla_forward(params, x, cfg: MLAConfig, positions,
                constrain: Constrain = NO_CONSTRAIN):
    """Prefill/train MLA. Returns (y, c_cache) with c = [c_kv ; k_rope]."""
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _mla_q(params, x, cfg, positions, constrain)
    c_kv = rmsnorm(params["kv_norm"], linear(params["w_dkv"], x))
    k_rope = linear(params["w_kr"], x)                       # (b, s, rope)
    k_rope = rope(k_rope, positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsc,chd->bshd", c_kv, params["w_uk"])
    v = jnp.einsum("bsc,chd->bshd", c_kv, params["w_uv"])
    k_nope = constrain(k_nope, ("batch", "seq", "heads", None))
    v = constrain(v, ("batch", "seq", "heads", None))
    q = jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, cfg.qk_rope_dim))],
        axis=-1).transpose(0, 2, 1, 3)
    k = constrain(k, ("batch", "heads", None, None))
    vh = v.transpose(0, 2, 1, 3)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    out = online_attention(q, k, vh, causal=True, chunk=cfg.chunk,
                           q_positions=positions[0],
                           kv_positions=positions[0], scale=scale)
    y = out.transpose(0, 2, 1, 3).reshape(b, s, h * cfg.v_head_dim)
    cache = jnp.concatenate([c_kv, k_rope], axis=-1)         # (b, s, 576)
    return linear(params["wo"], y), cache


def mla_decode(params, x, c_cache, pos, cfg: MLAConfig,
               constrain: Constrain = NO_CONSTRAIN, seq_axis="kv_seq"):
    """Absorbed-matmul MLA decode against the compressed (seq-sharded) cache."""
    b = x.shape[0]
    h = cfg.num_heads
    positions = jnp.full((b, 1), pos, jnp.int32)
    # as in attn_decode: replicate heads, parallelize over the sharded cache.
    decode_constrain: Constrain = lambda t, axes: constrain(
        t, tuple(None if a == "heads" else a for a in axes))
    q_nope, q_rope = _mla_q(params, x, cfg, positions, decode_constrain)
    c_kv = rmsnorm(params["kv_norm"], linear(params["w_dkv"], x))
    k_rope = rope(linear(params["w_kr"], x), positions, cfg.rope_theta)
    new = jnp.concatenate([c_kv, k_rope], axis=-1)
    c_cache = jax.lax.dynamic_update_slice(
        c_cache, new.astype(c_cache.dtype), (0, pos, 0))
    c_cache = constrain(c_cache, ("batch", seq_axis, None))
    cc, cr = c_cache[..., :cfg.kv_lora], c_cache[..., cfg.kv_lora:]
    # absorb W_uk into q:  q'[b,h,c] = sum_n q_nope[b,h,n] W_uk[c,h,n]
    q_abs = jnp.einsum("bhn,chn->bhc", q_nope[:, 0].astype(jnp.float32),
                       params["w_uk"].astype(jnp.float32))
    s = c_cache.shape[1]
    scores = (jnp.einsum("bhc,bsc->bhs", q_abs, cc.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                           cr.astype(jnp.float32)))
    scores = scores * (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    valid = jnp.arange(s)[None, None, :] <= pos
    w = jax.nn.softmax(jnp.where(valid, scores, -1e30), axis=-1)
    out_c = jnp.einsum("bhs,bsc->bhc", w, cc.astype(jnp.float32))
    out = jnp.einsum("bhc,chv->bhv", out_c,
                     params["w_uv"].astype(jnp.float32))
    y = out.reshape(b, 1, h * cfg.v_head_dim).astype(x.dtype)
    return linear(params["wo"], y), c_cache
