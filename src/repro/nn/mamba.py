"""Mamba (S6) selective-state-space mixer, chunked for TPU.

Train/prefill uses a chunked parallel scan: lax.scan over sequence chunks
with an associative scan inside each chunk, so the (B, chunk, d_inner, N)
working set stays VMEM-friendly and the d_inner channels shard over the
``model`` axis. Decode is a single recurrence step carrying
(conv_state, ssm_state).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import linear, linear_plan
from repro.nn.param import ParamSpec
from repro.nn.attention import Constrain, NO_CONSTRAIN


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    expand: int = 2
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0        # 0 -> ceil(d_model / 16)
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def mamba_plan(cfg: MambaConfig, dtype=jnp.bfloat16):
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.rank
    return {
        "in_proj": linear_plan(d, 2 * di, in_axis="embed", out_axis="state",
                               dtype=dtype),
        "conv_w": ParamSpec((cfg.d_conv, di), dtype, ("conv", "state"),
                            scale=0.5),
        "conv_b": ParamSpec((di,), dtype, ("state",), init="zeros"),
        "x_proj": linear_plan(di, r + 2 * n, in_axis="state", out_axis=None,
                              dtype=dtype),
        "dt_proj": linear_plan(r, di, in_axis=None, out_axis="state",
                               bias=True, dtype=dtype),
        "a_log": ParamSpec((di, n), jnp.float32, ("state", None),
                           init="zeros"),
        "d_skip": ParamSpec((di,), jnp.float32, ("state",), init="ones"),
        "out_proj": linear_plan(di, d, in_axis="state", out_axis="embed",
                                dtype=dtype),
    }


def _ssm_inputs(params, xz, cfg: MambaConfig):
    """Shared projections: returns (x, z, dt, b_in, c_out, a)."""
    di, n = cfg.d_inner, cfg.d_state
    x, z = xz[..., :di], xz[..., di:]
    proj = linear(params["x_proj"], x)
    dt_r = proj[..., :cfg.rank]
    b_in = proj[..., cfg.rank:cfg.rank + n].astype(jnp.float32)
    c_out = proj[..., cfg.rank + n:].astype(jnp.float32)
    dt = jax.nn.softplus(linear(params["dt_proj"], dt_r)
                         .astype(jnp.float32))                 # (..., di)
    a = -jnp.exp(params["a_log"])                              # (di, n)
    return x, z, dt, b_in, c_out, a


def _scan_chunk(x, dt, b_in, c_out, a, h0):
    """Associative scan within one chunk. x: (B, L, di); h0: (B, di, N)."""
    da = jnp.exp(dt[..., None] * a)                  # (B, L, di, N) decay
    db = dt[..., None] * b_in[:, :, None, :]         # (B, L, di, N)
    u = db * x.astype(jnp.float32)[..., None]

    def combine(l, r):
        al, ul = l
        ar, ur = r
        return al * ar, ur + ar * ul

    a_c, u_c = jax.lax.associative_scan(combine, (da, u), axis=1)
    h = a_c * h0[:, None] + u_c                      # (B, L, di, N)
    y = jnp.einsum("bldn,bln->bld", h, c_out)
    return y, h[:, -1]


def mamba_forward(params, x_in, cfg: MambaConfig,
                  constrain: Constrain = NO_CONSTRAIN):
    """x_in: (B, S, d). Returns (y, (conv_state, ssm_state)) for caching."""
    b, s, _ = x_in.shape
    di = cfg.d_inner
    xz = linear(params["in_proj"], x_in)
    xz = constrain(xz, ("batch", "seq", "state"))
    x, z = xz[..., :di], xz[..., di:]
    # causal depthwise conv via shift-and-add (d_conv is tiny)
    xp = jnp.pad(x, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + s] * params["conv_w"][i]
             for i in range(cfg.d_conv)) + params["conv_b"]
    x = jax.nn.silu(xc)
    xz2 = jnp.concatenate([x, z], axis=-1)
    x, z, dt, b_in, c_out, a = _ssm_inputs(params, xz2, cfg)

    chunk = min(cfg.chunk, s)
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} % mamba chunk {chunk} != 0"

    # checkpoint the chunk body: the associative-scan intermediates
    # ((B, chunk, d_inner, N) fp32 tensors) are recomputed in the backward
    # pass instead of being stacked across chunks (~20 GB/layer otherwise).
    @jax.checkpoint
    def body(h, inp):
        xb, dtb, bb, cb = inp
        y, h = _scan_chunk(xb, dtb, bb, cb, a, h)
        return h, y

    resh = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((b, di, cfg.d_state), jnp.float32)
    h_last, ys = jax.lax.scan(body, h0, (resh(x), resh(dt), resh(b_in),
                                         resh(c_out)))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + x.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_in.dtype)
    y = constrain(y, ("batch", "seq", "state"))
    conv_state = jnp.pad(x, ((0, 0), (cfg.d_conv - 1, 0), (0, 0))
                         )[:, -(cfg.d_conv - 1):].swapaxes(1, 2) \
        if cfg.d_conv > 1 else jnp.zeros((b, di, 0), x.dtype)
    return linear(params["out_proj"], y), (conv_state, h_last)


def mamba_decode(params, x_in, conv_state, ssm_state, cfg: MambaConfig,
                 constrain: Constrain = NO_CONSTRAIN):
    """One-step recurrence. x_in: (B, 1, d); conv_state (B, di, d_conv-1);
    ssm_state (B, di, N)."""
    b = x_in.shape[0]
    di = cfg.d_inner
    xz = linear(params["in_proj"], x_in)[:, 0]          # (B, 2di)
    x, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([conv_state, x[:, :, None]], axis=-1)
    # window[..., k]: oldest at k=0, matching the causal shift-and-add above
    xc = jnp.einsum("bdk,kd->bd", window.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32))
    xc = xc + params["conv_b"].astype(jnp.float32)
    x = jax.nn.silu(xc).astype(x_in.dtype)
    new_conv = window[..., 1:].astype(conv_state.dtype)
    xz2 = jnp.concatenate([x, z], axis=-1)[:, None]
    x1, z1, dt, b_in, c_out, a = _ssm_inputs(params, xz2, cfg)
    x1, z1, dt = x1[:, 0], z1[:, 0], dt[:, 0]
    b_in, c_out = b_in[:, 0], c_out[:, 0]
    da = jnp.exp(dt[..., None] * a)                      # (B, di, N)
    h = da * ssm_state + dt[..., None] * b_in[:, None, :] \
        * x1.astype(jnp.float32)[..., None]
    y = jnp.einsum("bdn,bn->bd", h, c_out)
    y = y + x1.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z1.astype(jnp.float32))).astype(x_in.dtype)
    return linear(params["out_proj"], y)[:, None], (new_conv, h)
