"""Gradient-synchronization control.

``grad_sync(w, axes, constrain)`` is an identity on the forward pass; on
the backward pass it (1) casts the weight cotangent to the weight dtype
(bf16 on the wire instead of f32 — 2x collective bytes) and (2) applies the
weight's sharding constraint to the cotangent, which turns GSPMD's
all-reduce-then-slice into a reduce-scatter (another ~2x). Applied to layer
parameters *inside* the scan body so the constraint lands on the
per-iteration gradient contraction.
"""
from __future__ import annotations

import jax

from repro.nn import param as prm


def grad_sync(w, axes: tuple, constrain):
    @jax.custom_vjp
    def ident(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, g):
        g = g.astype(w.dtype)
        return (constrain(g, axes),)

    ident.defvjp(fwd, bwd)
    return ident(w)


def sync_tree(params, plan, constrain):
    """Wrap every param leaf with grad_sync using its plan axes."""
    return jax.tree_util.tree_map(
        lambda p, s: grad_sync(p, s.axes, constrain), params, plan)
