"""Mixture-of-Experts: token-choice top-k routing with capacity gather.

Expert parallelism: expert-stacked weights shard their leading E dim over
the ``model`` mesh axis. Per expert we gather its top-capacity tokens,
run the expert FFN on the (E, C, d) bundle, and scatter-add back weighted
by the router gate — partial sums across expert shards are combined by the
GSPMD-inserted all-reduce. This is the dropless-ish capacity formulation
used by TPU MoE stacks (no (T, E, C) one-hot dispatch tensor is ever
materialized).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import act, linear_plan, linear
from repro.nn.param import ParamSpec
from repro.nn.attention import Constrain, NO_CONSTRAIN


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0          # total shared-expert hidden width
    capacity_factor: float = 1.25
    activation: str = "silu"
    router_dtype = jnp.float32


def moe_plan(cfg: MoEConfig, dtype=jnp.bfloat16):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": ParamSpec((d, e), jnp.float32, (None, None), scale=0.02),
        # 2D expert sharding: experts over `model` (EP), ffn-inner over
        # `data` (inner TP) — weights never gathered; activations move.
        "w_gate": ParamSpec((e, d, f), dtype, ("experts", None, "moe_f")),
        "w_up": ParamSpec((e, d, f), dtype, ("experts", None, "moe_f")),
        "w_down": ParamSpec((e, f, d), dtype, ("experts", "moe_f", None)),
    }
    if cfg.num_shared_experts:
        fs = cfg.d_ff_shared or cfg.num_shared_experts * f
        p["shared"] = {
            "gate": linear_plan(d, fs, in_axis="embed", out_axis="mlp",
                                dtype=dtype),
            "up": linear_plan(d, fs, in_axis="embed", out_axis="mlp",
                              dtype=dtype),
            "down": linear_plan(fs, d, in_axis="mlp", out_axis="embed",
                                dtype=dtype),
        }
    return p


def _capacity(group_tokens: int, cfg: MoEConfig) -> int:
    c = int(group_tokens * cfg.top_k * cfg.capacity_factor
            / cfg.num_experts)
    c = max(8, -(-c // 8) * 8)     # round up to 8 for TPU lane alignment
    return min(c, group_tokens)    # decode: never exceed the token count


def moe_forward(params, x, cfg: MoEConfig,
                constrain: Constrain = NO_CONSTRAIN):
    """x: (B, S, d) -> (y, aux_loss).

    GShard-style *local groups*: routing capacity is per batch row, so the
    expert gather/scatter stays local to the row's data shard — a global
    top-k over B*S tokens would force cross-shard sorts/gathers of the
    whole token stream (measured: ~60x the collective bytes). The gathered
    bundle is (B, E, C, d): B shards over batch axes, E over `model` (EP).
    """
    b, s, d = x.shape
    cap = _capacity(s, cfg)

    gates = (x.astype(jnp.float32) @ params["router"])       # (B, S, E)
    probs = jax.nn.softmax(gates, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)             # (B, S, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # per-token-per-expert combine weight (0 if not routed there)
    chose = jnp.zeros((b, s, cfg.num_experts), jnp.float32)
    chose = jax.vmap(jax.vmap(lambda c, i, v: c.at[i].set(v)))(
        chose, topi, topv)

    # load-balancing auxiliary loss (Switch-style, averaged over rows)
    me = probs.mean((0, 1))
    ce = (chose > 0).astype(jnp.float32).mean((0, 1))
    aux = cfg.num_experts * jnp.sum(me * ce)

    # expert-choice-of-routed-tokens per row: expert e takes its top-C
    # tokens of the row by combine weight; overflow drops (capacity slack).
    w_ec = chose.swapaxes(1, 2)                               # (B, E, S)
    top_w, top_idx = jax.lax.top_k(w_ec, cap)                 # (B, E, C)
    gathered = jax.vmap(lambda xb, ib: xb[ib.reshape(-1)])(
        x, top_idx).reshape(b, cfg.num_experts, cap, d)
    gathered = constrain(gathered, ("batch", "experts", None, None))

    # expert weights are STORED 2D-sharded (experts x moe_f) but COMPUTED
    # gathered over the inner dim (FSDP-on-experts): tokens keep their
    # batch sharding and the weight AG/grad-RS is tiny next to MoE compute
    # (a sharded-f einsum output would conflict with batch on `data` and
    # force activation reshards ~10x larger — see EXPERIMENTS §Perf).
    w_up = constrain(params["w_up"], ("experts", None, None))
    w_gate = constrain(params["w_gate"], ("experts", None, None))
    w_down = constrain(params["w_down"], ("experts", None, None))

    h = jnp.einsum("becd,edf->becf", gathered, w_up)
    g = jnp.einsum("becd,edf->becf", gathered, w_gate)
    h = h * act(cfg.activation)(g)
    out_e = jnp.einsum("becf,efd->becd", h, w_down)
    out_e = out_e * top_w[..., None].astype(out_e.dtype)
    out_e = constrain(out_e, ("batch", "experts", None, None))

    # shared experts computed FIRST and used as the scatter base: their
    # model-axis partial sum merges with the routed combine's partial sum
    # into a single all-reduce (instead of two full-activation ARs).
    if "shared" in params:
        sp = params["shared"]
        hs = linear(sp["up"], x) * act(cfg.activation)(linear(sp["gate"], x))
        base = linear(sp["down"], hs).astype(out_e.dtype)
    else:
        base = jnp.zeros((b, s, d), out_e.dtype)
    y = jax.vmap(lambda bb, ob, ib: bb.at[ib.reshape(-1)]
                 .add(ob.reshape(-1, d)))(base, out_e, top_idx)
    y = constrain(y, ("batch", "seq", "embed"))
    return y, aux
