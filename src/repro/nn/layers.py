"""Core layers as (plan, apply) pairs over the functional param system."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec

ACTIVATIONS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "identity": lambda x: x,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def act(name: str):
    return ACTIVATIONS[name]


# ---------------------------------------------------------------- linear --
def linear_plan(d_in: int, d_out: int, *, in_axis=None, out_axis=None,
                bias: bool = False, dtype=jnp.bfloat16):
    p = {"w": ParamSpec((d_in, d_out), dtype, (in_axis, out_axis))}
    if bias:
        p["b"] = ParamSpec((d_out,), dtype, (out_axis,), init="zeros")
    return p


def linear(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ------------------------------------------------------------------ norm --
def rmsnorm_plan(d: int, dtype=jnp.bfloat16, axis=None):
    return {"scale": ParamSpec((d,), dtype, (axis,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_plan(d: int, dtype=jnp.bfloat16, axis=None):
    return {"scale": ParamSpec((d,), dtype, (axis,), init="ones"),
            "bias": ParamSpec((d,), dtype, (axis,), init="zeros")}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------- embedding --
def embedding_plan(vocab: int, d: int, dtype=jnp.bfloat16):
    return {"table": ParamSpec((vocab, d), dtype, ("vocab", "embed"),
                               init="embed")}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


# --------------------------------------------------------------- MLP ffn --
def mlp_plan(d: int, d_ff: int, *, gated: bool = True, dtype=jnp.bfloat16):
    p = {"up": linear_plan(d, d_ff, in_axis="embed", out_axis="mlp",
                           dtype=dtype),
         "down": linear_plan(d_ff, d, in_axis="mlp", out_axis="embed",
                             dtype=dtype)}
    if gated:
        p["gate"] = linear_plan(d, d_ff, in_axis="embed", out_axis="mlp",
                                dtype=dtype)
    return p


def mlp(params, x, activation: str = "silu"):
    h = linear(params["up"], x)
    if "gate" in params:
        h = h * act(activation)(linear(params["gate"], x))
    else:
        h = act(activation)(h)
    return linear(params["down"], h)


# ------------------------------------------------- chunked cross-entropy --
def chunked_softmax_xent(x, out_table, labels, *, chunk: int = 1024,
                         label_mask=None, table_grad_sync=None):
    """Cross-entropy with the final projection computed in sequence chunks.

    Bounds the logits working set to (batch, chunk, vocab) — required for
    256k-vocab models (minitron) where full logits would be hundreds of GB.
    lax.scan keeps chunk lifetimes serial (an unrolled loop lets the
    scheduler keep every chunk's table-gradient alive at once).
    ``table_grad_sync`` (from nn.gradsync) is applied *inside* the body so
    each chunk's out_table cotangent reduce-scatters in bf16 and the scan
    transpose accumulates it sharded. Returns (mean_loss, total_weight).
    """
    b, s, d = x.shape
    n = max(s // chunk, 1)
    chunk = s // n
    assert n * chunk == s, f"seq {s} not divisible by xent chunk {chunk}"
    if label_mask is None:
        label_mask = jnp.ones((b, s), jnp.float32)
    xc = x.reshape(b, n, chunk, d).swapaxes(0, 1)
    yc = labels.reshape(b, n, chunk).swapaxes(0, 1)
    mc = label_mask.reshape(b, n, chunk).swapaxes(0, 1).astype(jnp.float32)

    def body(carry, inp):
        loss_sum, w_sum = carry
        xb, yb, mb = inp
        table = table_grad_sync(out_table) if table_grad_sync else out_table
        logits = (xb @ table).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mb
        return (loss_sum + nll.sum(), w_sum + mb.sum()), None

    (loss_sum, w_sum), _ = jax.lax.scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, yc, mc))
    return loss_sum / jnp.maximum(w_sum, 1.0), w_sum
