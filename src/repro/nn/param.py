"""Parameter plans: declarative parameter trees with logical sharding axes.

A *plan* is a pytree (nested dicts) of :class:`ParamSpec`. Models declare
plans; the runtime can then

* ``materialize(plan, key)``  -> real arrays (smoke tests, examples),
* ``abstract(plan)``          -> ShapeDtypeStructs (dry-run, no allocation),
* ``logical_axes(plan)``      -> pytree of logical-axis tuples,

and ``distributed.sharding`` maps logical axes -> mesh PartitionSpecs.
This mirrors GNNBuilder's split between the *design* (template parameters)
and the *synthesized artifact* (the compiled program).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Axes = tuple  # tuple[str | None, ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative spec for one parameter tensor."""

    shape: tuple
    dtype: Any = jnp.bfloat16
    axes: Axes = ()           # logical axis name per dim (str or None)
    init: str = "normal"      # normal | zeros | ones | embed | scaled
    scale: float | None = None  # stddev override for normal/scaled

    def __post_init__(self):
        if len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank mismatch with shape {self.shape}")

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], plan):
    return jax.tree_util.tree_map(fn, plan, is_leaf=is_spec)


def abstract(plan):
    """ShapeDtypeStruct tree for dry-run lowering (no device allocation)."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), plan)


def logical_axes(plan):
    return tree_map_specs(lambda s: s.axes, plan)


def count_params(plan) -> int:
    leaves = jax.tree_util.tree_leaves(plan, is_leaf=is_spec)
    return sum(l.size for l in leaves if is_spec(l))


def _init_one(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = spec.scale or 0.02
        return (jax.random.normal(key, spec.shape, jnp.float32) * std
                ).astype(spec.dtype)
    # normal / scaled: fan-in scaled truncated-normal-ish init.
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * std
            ).astype(spec.dtype)


def materialize(plan, key):
    """Instantiate real arrays for a plan (used by smoke tests/examples)."""
    leaves, treedef = jax.tree_util.tree_flatten(plan, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(l, k) for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def stack_plan(plan, n: int, axis_name: str = "layers"):
    """Plan for ``n`` scanned copies: prepend a leading stacking axis."""
    return tree_map_specs(
        lambda s: ParamSpec((n,) + tuple(s.shape), s.dtype,
                            (axis_name,) + tuple(s.axes), s.init, s.scale),
        plan)


def cast_plan(plan, dtype):
    return tree_map_specs(
        lambda s: ParamSpec(s.shape, dtype, s.axes, s.init, s.scale), plan)
