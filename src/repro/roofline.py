"""Roofline analysis over compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (v5e constants):

  compute    = HLO_dot_FLOPs_per_chip / 197e12        (bf16 MXU peak)
  memory     = HLO_dot_bytes_per_chip / 819e9         (HBM)
  collective = collective_bytes_per_chip / 50e9       (per-link ICI)

HLO_dot_FLOPs/bytes come from parsing every `dot` in the compiled
per-device HLO scaled by scan trip counts (distributed.hlo.dot_stats) —
``cost_analysis()`` counts loop bodies once and is reported only as a
diagnostic. Collective bytes use the tpu-adjusted accounting
(hlo._line_collective docstring). The memory term is a *matmul-traffic*
bound (elementwise/norm traffic excluded; true HBM time is slightly
higher on memory-bound cells).

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params
(MoE: shared + top_k/E of routed), D = processed tokens. The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat recompute, TP head padding, MoE
capacity slack and attention FLOPs.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

from repro.configs.common import SHAPES, applicable_shapes
from repro.configs.registry import ARCHS, get_config
from repro.models import lm
from repro.nn import param as prm

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_BYTES = 16e9


def param_counts(cfg) -> dict:
    """(total, active) params; active discounts non-routed experts."""
    plan = lm.model_plan(cfg)
    total = prm.count_params(plan)
    expert = 0
    for leaf in __import__("jax").tree_util.tree_leaves(
            plan, is_leaf=prm.is_spec):
        if prm.is_spec(leaf) and "experts" in leaf.axes:
            expert += leaf.size
    active = total - expert
    if cfg.moe is not None and expert:
        active += expert * cfg.moe.top_k / cfg.moe.num_experts
    return {"total": total, "active": int(active)}


def model_flops(cfg, shape_name: str) -> float:
    """Assignment formula: 6*N_active*D (train), 2*N_active*D (inference),
    global across chips."""
    info = SHAPES[shape_name]
    n_active = param_counts(cfg)["active"]
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        if cfg.family == "audio":
            tokens = info["batch"] * (info["seq"] // cfg.dec_len_ratio)
        return 6.0 * n_active * tokens
    if info["kind"] == "prefill":
        tokens = info["batch"] * info["seq"]
        if cfg.family == "audio":
            tokens = info["batch"] * (info["seq"] // cfg.dec_len_ratio)
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * info["batch"]


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    variant: str
    ok: bool
    n_devices: int = 0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    collective_raw_s: float = 0.0
    model_flops: float = 0.0
    hlo_flops_chip: float = 0.0
    flops_ratio: float = 0.0      # MODEL / (HLO x chips)
    hbm_gb: float = 0.0           # args + temp per device
    fits: bool = True
    dominant: str = ""
    mitigation: str = ""
    compile_s: float = 0.0
    error: str = ""

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs throughput / peak, at the modeled step time."""
        if self.step_s <= 0 or self.n_devices == 0:
            return 0.0
        return (self.model_flops / self.n_devices / self.step_s) \
            / PEAK_FLOPS


MITIGATIONS = {
    "compute": ("cut recompute (remat policy / fewer microbatch passes) "
                "and head-padding waste; compute is already the right "
                "place to be"),
    "memory": ("raise arithmetic intensity: larger per-chip batch/tile, "
               "fuse elementwise into matmuls, quantize weights (int8) "
               "to halve weight traffic"),
    "collective": ("re-shard: move batch over more axes / gather weights "
                   "instead of activations (or vice versa), overlap "
                   "collectives with compute (async schedule)"),
}


def analyse_record(rec: dict) -> Cell:
    cell = Cell(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                variant=rec.get("variant", ""), ok=rec.get("ok", False),
                n_devices=rec.get("n_devices", 0),
                compile_s=rec.get("compile_s", 0.0),
                error=rec.get("error", ""))
    if not cell.ok:
        return cell
    cfg = get_config(rec["arch"])
    cell.hlo_flops_chip = rec.get("hlo_dot_flops", 0.0)
    cell.compute_s = cell.hlo_flops_chip / PEAK_FLOPS
    cell.memory_s = rec.get("hlo_dot_bytes", 0.0) / HBM_BW
    cell.collective_s = rec.get("collective_bytes_tpu", 0.0) / LINK_BW
    cell.collective_raw_s = rec.get("collective_bytes", 0.0) / LINK_BW
    cell.model_flops = model_flops(cfg, rec["shape"])
    denom = cell.hlo_flops_chip * max(cell.n_devices, 1)
    cell.flops_ratio = cell.model_flops / denom if denom else 0.0
    mem = rec.get("memory", {})
    cell.hbm_gb = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 1e9
    # CPU-XLA upcasts bf16 activations to f32; TPU temp ~ half. Judge fit
    # against the adjusted estimate, report both.
    cell.fits = (mem.get("argument_size_in_bytes", 0)
                 + mem.get("temp_size_in_bytes", 0) / 2) < HBM_BYTES
    terms = {"compute": cell.compute_s, "memory": cell.memory_s,
             "collective": cell.collective_s}
    cell.dominant = max(terms, key=terms.get)
    cell.mitigation = MITIGATIONS[cell.dominant]
    return cell


def load_cells(results_dir: str, variant: str | None = None) -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if variant and rec.get("variant") != variant:
            continue
        cells.append(analyse_record(rec))
    return cells


def skipped_cells() -> list:
    """Explicit SKIPPED rows so the 40-cell accounting is complete."""
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape not in applicable_shapes(cfg):
                rows.append((arch, shape,
                             "SKIPPED: full-attention arch; long_500k "
                             "needs sub-quadratic attention (DESIGN.md)"))
    return rows


def markdown_table(cells: list, mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s "
           "(raw) | dominant | MODEL/HLO | roofline frac | HBM GB/dev "
           "| fits |\n|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for c in sorted(cells, key=lambda c: (c.arch, c.shape)):
        if c.mesh != mesh:
            continue
        if not c.ok:
            out.append(f"| {c.arch} | {c.shape} | FAILED: {c.error[:60]} "
                       "| | | | | | | |\n")
            continue
        out.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3f} | "
            f"{c.memory_s:.3f} | {c.collective_s:.3f} "
            f"({c.collective_raw_s:.3f}) | **{c.dominant}** | "
            f"{c.flops_ratio:.2f} | {c.roofline_fraction * 100:.1f}% | "
            f"{c.hbm_gb:.1f} | {'yes' if c.fits else 'NO'} |\n")
    for arch, shape, note in skipped_cells():
        out.append(f"| {arch} | {shape} | {note} | | | | | | | |\n")
    return "".join(out)
