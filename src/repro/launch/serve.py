"""Batched serving drivers.

LM mode (default): prefill + decode loop with donated KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
      --batch 4 --prompt-len 32 --gen 32

GNN mode (--gnn): drains a graph request queue through fixed-shape packed
GraphBatch programs — one jitted program, budget-sized buffers, reported
in graphs/s (DESIGN_BATCHING.md). Admission mirrors the continuous
scheduler's statuses: malformed graphs are rejected explicitly
(``rejected_invalid``, data.pipeline.validate_graph), and requests too
large for the packed budgets split across the local device pool through
the intra-graph partitioned SPMD program when >= 2 devices exist
(``partitioned_served``; halo exchange between layers, docs/SERVING.md)
— the padded per-graph oracle stays as the no-mesh fallback
(``fallback_served``), and with neither program oversize requests get
per-request ``rejected_oversize`` outcomes, never a silent drop. ``--precision``
serves through
a low-precision PrecisionPolicy datapath (bf16 / int8 tiles, fp32
accumulation; int8 grids are max-abs calibrated on the warmup batch) and
reports the output error vs the fp32 program next to the throughput.

``--shards N`` drains the queue into per-device packed shard waves over
a ("data",) device mesh instead — one SPMD program, params replicated,
each device consuming its own shard (the oversize fallback is
unchanged).

``--scheduler continuous`` swaps the synchronous wave drain for the
continuous-batching scheduler (runtime.scheduler): the queue is
replayed as an open-loop Poisson arrival process at ``--load`` graphs/s
on a virtual clock, requests feed continuously into partially-filled
packed batches, and a batch launches on ``--deadline-ms`` expiry or
budget-full; measured service times make the reported p50/p99
traffic-shaped while the compute is real. Full lifecycle:
docs/SERVING.md.

  PYTHONPATH=src python -m repro.launch.serve --gnn --conv gcn \
      --requests 256 --batch-graphs 32 [--agg-backend pallas] \
      [--dataflow auto|aggregate_first|transform_first] \
      [--precision fp32|bf16|int8] [--shards 4] \
      [--scheduler continuous --load 512 --deadline-ms 50]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.core import convs as Cv
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.nn import param as prm


def pad_caches(prefill_caches, full_caches):
    """Write prompt-length caches into the full-length serving buffers."""
    def place(full, part):
        if full.shape == part.shape:
            return part.astype(full.dtype)
        return jax.lax.dynamic_update_slice(
            full, part.astype(full.dtype), (0,) * full.ndim)
    return jax.tree_util.tree_map(place, full_caches, prefill_caches)


def _fallback_input(g) -> dict:
    """Padded per-graph oracle input for one oversize Graph request."""
    return {"node_feat": jnp.asarray(g.node_feat),
            "edge_index": jnp.asarray(g.edge_index),
            "edge_feat": jnp.asarray(g.edge_feat),
            "num_nodes": jnp.int32(g.num_nodes)}


def _admit(queue, node_budget: int, edge_budget: int, *,
           can_fallback: bool, can_partition: bool = False,
           validate: bool = True):
    """Admission screen of the wave drains, mirroring the continuous
    scheduler's ``submit``: every request is routed to exactly one
    outcome up front — packable, oversize (answered by the partitioned
    SPMD program when ``can_partition``, else the padded fallback), or
    an explicit per-request rejection (``rejected_oversize`` when
    neither oversize program exists, ``rejected_invalid`` when
    ``validate_graph`` says the graph is malformed) — never a silent
    drop. The classification is *mesh-aware*: ``can_partition`` is the
    same predicate the continuous scheduler's executors advertise, so
    the wave drains and the scheduler agree on which program answers an
    oversize request. Returns (packable, oversize, outcomes);
    ``outcomes[i]`` carries the queue index, the status
    (continuous-scheduler status names — oversize statuses are the
    *planned* route, reconciled to the actual one after launch), and a
    reason for rejections."""
    from repro.data import pipeline as P
    from repro.runtime import scheduler as S
    packable, oversize, outcomes = [], [], []
    for i, g in enumerate(queue):
        if validate:
            reason = P.validate_graph(g)
            if reason is not None:
                outcomes.append({"index": i, "status": S.REJECTED_INVALID,
                                 "reason": reason})
                continue
        if P.graph_fits_budget(g, node_budget, edge_budget):
            packable.append(g)
            outcomes.append({"index": i, "status": S.SERVED_PACKED})
        elif can_partition or can_fallback:
            oversize.append(g)
            outcomes.append({"index": i, "status":
                             S.SERVED_PARTITIONED if can_partition
                             else S.SERVED_FALLBACK})
        else:
            outcomes.append({
                "index": i, "status": S.REJECTED_OVERSIZE,
                "reason": f"{g.num_nodes} nodes/{g.num_edges} edges exceed "
                          f"the packed budgets ({node_budget} nodes/"
                          f"{edge_budget} edges) and no partitioned or "
                          "fallback program is available"})
    return packable, oversize, outcomes


def _reconcile_oversize(outcomes, over_status):
    """Rewrite the oversize outcomes' *planned* route with the actual
    post-launch one (partition infeasibility reroutes a graph to the
    padded fallback, or to an explicit rejection when none exists), so
    ``outcomes`` and the partitioned/fallback counts always agree."""
    from repro.runtime import scheduler as S
    it = iter(over_status)
    for o in outcomes:
        if o["status"] in (S.SERVED_PARTITIONED, S.SERVED_FALLBACK):
            o["status"] = next(it)
    return outcomes


def _rejection_stats(stats: dict, outcomes) -> dict:
    """Fold per-request admission outcomes into a wave drain's stats.
    ``dropped`` stays as a legacy alias of ``rejected_oversize``."""
    from repro.runtime import scheduler as S
    stats["outcomes"] = outcomes
    stats["rejected_oversize"] = sum(
        1 for o in outcomes if o["status"] == S.REJECTED_OVERSIZE)
    stats["rejected_invalid"] = sum(
        1 for o in outcomes if o["status"] == S.REJECTED_INVALID)
    stats["dropped"] = stats["rejected_oversize"]
    return stats


def _launch_packed(run_batch, batches, oversize, fallback_fn, *,
                   graphs_in, slots_in, slot_capacity: int,
                   partition_fn=None):
    """Shared pack-and-launch body of the wave drains (and of anything
    else that runs a prepacked batch list): run every batch through
    ``run_batch``, answer oversize requests through ``partition_fn``
    (the intra-graph partitioned SPMD program; returns None when the
    graph cannot split under the per-device budgets) and ``fallback_fn``
    (the padded per-graph oracle on a ``_fallback_input`` dict), block,
    and account. Each oversize graph resolves to exactly one of
    partitioned / fallback / rejected-oversize — never double-counted.
    ``graphs_in``/``slots_in`` count the graphs and occupied node slots
    of one batch (they differ between the single-device and sharded
    layouts). Returns (batch_outs, oversize_outs, oversize_statuses,
    stats); ``oversize_outs``/``oversize_statuses`` line up with
    ``oversize`` (rejected graphs carry a None output)."""
    from repro.runtime import scheduler as S
    outs = []
    served = 0
    slots_used = 0
    t0 = time.perf_counter()
    for b in batches:
        outs.append(run_batch(b))
        served += graphs_in(b)
        slots_used += slots_in(b)
    over_outs, over_status = [], []
    for g in oversize:
        out = None if partition_fn is None else partition_fn(g)
        if out is not None:
            over_outs.append(out)
            over_status.append(S.SERVED_PARTITIONED)
        elif fallback_fn is not None:
            over_outs.append(fallback_fn(_fallback_input(g)))
            over_status.append(S.SERVED_FALLBACK)
        else:
            over_outs.append(None)
            over_status.append(S.REJECTED_OVERSIZE)
    live = [o for o in over_outs if o is not None]
    jax.block_until_ready(outs + live)
    total_s = time.perf_counter() - t0
    n_part = over_status.count(S.SERVED_PARTITIONED)
    n_fallback = over_status.count(S.SERVED_FALLBACK)
    stats = {
        "served": served + n_part + n_fallback,
        "packed_served": served,
        "partitioned_served": n_part,
        "fallback_served": n_fallback,
        "n_batches": len(batches),
        "graphs_per_s": (served + n_part + n_fallback)
        / max(total_s, 1e-12),
        "node_slot_utilization": slots_used / max(slot_capacity, 1),
        "total_s": total_s,
    }
    return outs, over_outs, over_status, stats


def drain_gnn_queue(fn, params, queue, node_budget: int, edge_budget: int,
                    batch_graphs: int, fallback_fn=None, *,
                    partition_fn=None, validate: bool = True):
    """Synchronous wave drain of ``queue`` (a list of data.pipeline.Graph
    requests) through the packed program ``fn``; every call sees the same
    static shapes, so XLA compiles exactly once. Returns
    (outputs per batch, stats).

    Request lifecycle (docs/SERVING.md): requests that fit the budgets
    are greedily packed into fixed-shape GraphBatches and answered by
    the packed program. Requests too large for the budgets cannot ride
    a GraphBatch; with ``partition_fn`` (the intra-graph partitioned
    SPMD program, ``G.apply_packed_partitioned`` behind a
    graph -> output-or-None callable) each one splits across the device
    mesh and ``stats["partitioned_served"]`` counts them; with
    ``fallback_fn`` (the padded per-graph oracle ``G.apply``, jitted)
    graphs the partitioner cannot split — or every oversize graph when
    no mesh exists — are answered individually through it
    (``stats["fallback_served"]``). Without either program each
    oversize request gets an explicit per-request ``rejected_oversize``
    outcome, and malformed graphs get ``rejected_invalid``
    (``validate=False`` skips the screen) — ``stats["outcomes"]`` lists
    every request's status under the same names the continuous
    scheduler uses, and ``stats["dropped"]`` stays as a legacy alias of
    ``rejected_oversize``.

    This drain is the offline-throughput baseline (and parity oracle)
    for the continuous-batching scheduler — see
    ``drain_gnn_queue_continuous`` for the latency-aware path."""
    from repro.core import gnn_model as G
    from repro.data import pipeline as P
    packable, oversize, outcomes = _admit(
        queue, node_budget, edge_budget,
        can_fallback=fallback_fn is not None,
        can_partition=partition_fn is not None, validate=validate)
    batches, leftover = P.pack_dataset(packable, node_budget, edge_budget,
                                       batch_graphs)
    assert not leftover, "_admit already screened for budget fit"
    outs, over_outs, over_status, stats = _launch_packed(
        lambda b: fn(params, G.packed_to_device(b)), batches, oversize,
        None if fallback_fn is None else (lambda el: fallback_fn(params, el)),
        partition_fn=partition_fn,
        graphs_in=lambda b: int(b["num_graphs"]),
        slots_in=lambda b: int((b["node_graph_id"] < batch_graphs).sum()),
        slot_capacity=len(batches) * node_budget)
    _reconcile_oversize(outcomes, over_status)
    return outs + [o for o in over_outs if o is not None], \
        _rejection_stats(stats, outcomes)


def drain_gnn_queue_sharded(fn, params, queue, node_budget: int,
                            edge_budget: int, batch_graphs: int,
                            num_shards: int, fallback_fn=None,
                            task: str = "graph", *, partition_fn=None,
                            validate: bool = True):
    """Sharded wave drain: requests are partitioned into per-device shard
    waves (data.pipeline.pack_dataset(num_shards=)) and each wave runs
    as one SPMD program over the ("data",) mesh — ``fn`` from
    ``gnn_model.make_sharded_apply``, compiled exactly once. Graph-task
    outputs come back in wave host order (gather_shard_outputs); node
    tasks (``task="node"``) get the raw stacked per-shard node tables
    per wave — their row order is shard-local, so there is no global
    host order to restore. Oversize requests behave exactly as in
    ``drain_gnn_queue`` (same ``_launch_packed`` body: partitioned SPMD
    program first, padded fallback second, explicit rejection last),
    and so do the per-request rejection outcomes (same ``_admit``
    screen)."""
    from repro.core import gnn_model as G
    from repro.data import pipeline as P
    packable, oversize, outcomes = _admit(
        queue, node_budget, edge_budget,
        can_fallback=fallback_fn is not None,
        can_partition=partition_fn is not None, validate=validate)
    waves, leftover = P.pack_dataset(packable, node_budget, edge_budget,
                                     batch_graphs, num_shards=num_shards)
    assert not leftover, "_admit already screened for budget fit"
    dev_outs, over_outs, over_status, stats = _launch_packed(
        lambda w: fn(params, G.stack_shards(w)), waves, oversize,
        None if fallback_fn is None else (lambda el: fallback_fn(params, el)),
        partition_fn=partition_fn,
        graphs_in=lambda w: w.n_graphs,
        slots_in=lambda w: sum(int((b["node_graph_id"]
                                    < batch_graphs).sum())
                               for b in w.shards),
        slot_capacity=len(waves) * num_shards * node_budget)
    stats["num_shards"] = num_shards
    _reconcile_oversize(outcomes, over_status)
    if task == "graph":
        outs = [P.gather_shard_outputs(np.asarray(o), w.index)
                for w, o in zip(waves, dev_outs)]
    else:
        outs = dev_outs
    return outs + [o for o in over_outs if o is not None], \
        _rejection_stats(stats, outcomes)


def _partition_or_infeasible(partition_fn, g):
    """Adapt the wave drains' graph -> output-or-None partition callable
    to the continuous scheduler's executor protocol, where infeasibility
    is the explicit ``PartitionInfeasible`` routing signal."""
    from repro.runtime import scheduler as S
    out = partition_fn(g)
    if out is None:
        raise S.PartitionInfeasible(
            f"{g.num_nodes} nodes/{g.num_edges} edges cannot split under "
            "the per-device budgets")
    return out


def drain_gnn_queue_continuous(fn, params, queue, node_budget: int,
                               edge_budget: int, batch_graphs: int,
                               fallback_fn=None, *, partition_fn=None,
                               load_graphs_per_s: float = 512.0,
                               deadline_s: float = 0.05,
                               max_queue_depth: int = 1024,
                               launch_timeout_s: float = float("inf"),
                               max_retries: int = 2,
                               validate: bool = True,
                               seed: int = 0):
    """Continuous-batching drain (``runtime.scheduler``): the queue is
    replayed as an open-loop Poisson arrival process at
    ``load_graphs_per_s`` on the scheduler's virtual clock, while each
    launch's service time is the *measured* wall-seconds of the real
    packed program (``MeasuredExecutor``) — so the p50/p99 latency
    statistics are traffic-shaped, the compute cost is real, and the
    outputs are the real program's outputs (parity with the wave
    drain). Batches launch on deadline expiry or budget-full; oversize
    requests ride ``partition_fn`` (the intra-graph partitioned SPMD
    program; raise ``scheduler.PartitionInfeasible`` inside it to
    reroute a graph to the oracle) then ``fallback_fn``; admissions
    beyond ``max_queue_depth``
    (or malformed graphs, when ``validate``) are rejected explicitly.
    The fault-tolerance knobs ride through: a launch not complete
    within ``launch_timeout_s`` of virtual time fails as a hang and its
    requests re-pack onto healthy lanes, up to ``max_retries`` times
    each before the dead-letter ``failed`` status (docs/SERVING.md
    §Fault tolerance). Returns (responses, stats) — ``responses`` are
    ``runtime.scheduler.Response`` records carrying per-request outputs
    and latencies. Lifecycle: docs/SERVING.md."""
    from repro.core import gnn_model as G
    from repro.runtime import scheduler as S
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xA221]))
    t = 0.0
    trace = []
    for g in queue:
        t += float(rng.exponential(1.0 / load_graphs_per_s))
        trace.append((t, g, "default"))
    executor = S.MeasuredExecutor(
        batch_fn=lambda b: np.asarray(jax.block_until_ready(
            fn(params, G.packed_to_device(b)))),
        fallback_fn=None if fallback_fn is None else (lambda g: np.asarray(
            jax.block_until_ready(fallback_fn(params, _fallback_input(g))))),
        partition_fn=None if partition_fn is None else (
            lambda g: np.asarray(jax.block_until_ready(
                _partition_or_infeasible(partition_fn, g)))))
    sched = S.ContinuousScheduler(
        S.SchedulerConfig(node_budget, edge_budget, batch_graphs,
                          max_queue_depth=max_queue_depth,
                          default_tier=S.SLOTier("standard", deadline_s, 1),
                          launch_timeout_s=launch_timeout_s,
                          max_retries=max_retries, validate=validate),
        executor)
    S.run_trace(sched, trace)
    stats = sched.summary()
    stats["n_batches"] = stats["n_launches"]
    stats["offered_load_graphs_per_s"] = load_graphs_per_s
    stats["deadline_s"] = deadline_s
    return sched.responses, stats


def gnn_main(args):
    from repro.configs.gnn import DATASETS, config as gnn_config
    from repro.core import aggregations as agg_mod
    from repro.core import gnn_model as G
    from repro.data import pipeline as P

    # single-device serving may opt into the fused Pallas segment kernel
    # (Mosaic-compiled on TPU, interpreted elsewhere — resolved by the
    # aggregation defaults); the default stays XLA, the safe choice under
    # pjit and on CPU hosts
    agg_mod.set_default_backend(args.agg_backend)
    cfg = gnn_config(args.conv, reduced=args.reduced)
    ds = DATASETS["qm9"]
    cfg = dataclasses.replace(cfg, gnn_dataflow=args.dataflow,
                              avg_degree=float(ds.avg_degree),
                              gnn_precision=args.precision)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    queue = [P.make_graph(ds, i) for i in range(args.requests)]
    node_budget = P.size_budget(args.batch_graphs, ds.avg_nodes)
    edge_budget = P.size_budget(args.batch_graphs,
                                ds.avg_nodes * ds.avg_degree)
    if args.oversize_requests > 0:
        # giant-graph traffic: requests that exceed the packed budgets
        # and exercise the oversize lifecycle (partitioned mesh program,
        # else padded oracle — docs/SERVING.md). 1.2x the node budget
        # keeps ceil(n/P) owned rows + the BFS-frontier halo inside the
        # per-device budget even on a 2-device mesh
        big_cfg = dataclasses.replace(
            ds, avg_nodes=int(1.2 * node_budget),
            max_nodes=max(ds.max_nodes, 4 * node_budget),
            max_edges=max(ds.max_edges, 4 * edge_budget),
            seed=ds.seed + 0x0B1)
        queue += [P.make_graph(big_cfg, i)
                  for i in range(args.oversize_requests)]
    # precision datapath: resolve the policy once; int8 grids are
    # max-abs calibrated on the warmup window. Oversize requests can't
    # ride a GraphBatch (pack_graphs would raise on them) — they are
    # excluded from the calibration batch and still get served through
    # the padded fallback below.
    warm = queue[:args.batch_graphs]
    warm_fit = [g for g in warm
                if P.graph_fits_budget(g, node_budget, edge_budget)]
    warm_batch = None
    if warm_fit:
        warm_batch, _ = P.pack_graphs(warm_fit, node_budget, edge_budget,
                                      args.batch_graphs)
        policy = G.calibrated_policy(params, cfg,
                                     G.packed_to_device(warm_batch))
    else:   # nothing packable to calibrate on: uncalibrated grids
        policy = G.resolve_policy(cfg)
    fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b, None, policy))
    # oversize requests fall back to the padded per-graph oracle so every
    # request is answered, not silently dropped
    fallback_fn = jax.jit(lambda p, el: G.apply(p, cfg, el, None, policy))

    # mesh-aware oversize routing: with >= 2 local devices, oversize
    # graphs split across the whole device pool and run through the
    # partitioned SPMD program (apply_packed_partitioned); the padded
    # oracle stays as the no-mesh fallback and the escape hatch for
    # graphs the partitioner cannot split under the per-device budgets
    partition_fn = None
    n_dev = len(jax.devices())
    if n_dev >= 2:
        from repro.launch.mesh import make_data_mesh
        part_mesh = make_data_mesh(n_dev)

        def partition_fn(g):
            try:
                part = P.partition_graph(g, n_dev, node_budget,
                                         edge_budget)
            except ValueError:
                return None
            return G.apply_packed_partitioned(params, cfg, part,
                                              part_mesh, None, policy)

    if args.scheduler == "continuous" and args.shards > 1:
        raise SystemExit("--scheduler continuous drives a single-host "
                         "executor; drop --shards or use --scheduler wave")

    if args.shards > 1:
        # data-parallel sharded drain: waves of per-device shards over a
        # ("data",) mesh, params replicated, one SPMD program
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.shards)
        sharded_fn = G.make_sharded_apply(cfg, mesh, None, policy)

        def drain(q):
            return drain_gnn_queue_sharded(
                sharded_fn, params, q, node_budget, edge_budget,
                args.batch_graphs, args.shards, fallback_fn,
                task=cfg.task, partition_fn=partition_fn)
    else:
        def drain(q):
            return drain_gnn_queue(fn, params, q, node_budget,
                                   edge_budget, args.batch_graphs,
                                   fallback_fn, partition_fn=partition_fn)

    # warmup: compile the single fixed-shape program
    _, _ = drain(warm)

    if args.scheduler == "continuous":
        # continuous batching: open-loop Poisson arrivals on the virtual
        # clock, measured service times, deadline/budget-full launches
        _, stats = drain_gnn_queue_continuous(
            fn, params, queue, node_budget, edge_budget,
            args.batch_graphs, fallback_fn, partition_fn=partition_fn,
            load_graphs_per_s=args.load, deadline_s=args.deadline_ms / 1e3,
            max_queue_depth=args.queue_depth,
            launch_timeout_s=(args.launch_timeout_ms / 1e3
                              if args.launch_timeout_ms > 0
                              else float("inf")),
            max_retries=args.max_retries)
        stats["precision"] = policy.name

        def ms(v):          # None when served == 0 — print it honestly
            return "n/a" if v is None else f"{v * 1e3:.1f} ms"
        print(f"conv={args.conv} precision={policy.name} continuous "
              f"scheduler served {stats['served']}/{len(queue)} graphs in "
              f"{stats['n_batches']} launches at "
              f"{args.load:.0f} offered graphs/s "
              f"(p50 {ms(stats['p50_latency_s'])}, "
              f"p99 {ms(stats['p99_latency_s'])}, batch fill "
              f"{stats['mean_batch_fill'] * 100:.0f}%, sustained "
              f"{stats['graphs_per_s']:.0f} graphs/s, "
              f"{stats['partitioned_served']} oversize via partitioned "
              f"mesh, "
              f"{stats['fallback_served']} oversize via padded fallback, "
              f"{stats['rejected_queue_full']} rejected by backpressure, "
              f"{stats['rejected_invalid']} invalid, "
              f"{stats['failed']} failed after retries)")
        return stats
    _, stats = drain(queue)
    stats["precision"] = policy.name
    stats["compute_bytes"] = policy.compute_bytes
    if not policy.is_fp32 and warm_batch is not None:
        # per-precision parity: output error of the low-precision program
        # vs the fp32 program on the warmup batch (pin an explicit fp32
        # policy — cfg.gnn_precision must not leak into the reference)
        from repro.core import quantization as Q
        fp32 = Q.resolve_policy("fp32", cfg.gnn_num_layers)
        dev = G.packed_to_device(warm_batch)
        ref = jax.jit(lambda p, b: G.apply_packed(
            p, cfg, b, None, fp32))(params, dev)
        got = fn(params, dev)
        k = int(warm_batch["num_graphs"])
        stats["output_error_vs_fp32"] = Q.error_stats(
            np.asarray(got)[:k], np.asarray(ref)[:k])
    err = stats.get("output_error_vs_fp32")
    err_txt = "" if err is None else \
        f", |err vs fp32| max {err['max_abs']:.2e} " \
        f"(SQNR {err['sqnr_db']:.0f} dB)"
    shards_txt = "" if args.shards <= 1 else \
        f" over {args.shards} device shards"
    print(f"conv={args.conv} precision={policy.name} served "
          f"{stats['served']} graphs in "
          f"{stats['n_batches']} packed batches{shards_txt} "
          f"({stats['graphs_per_s']:.0f} graphs/s, node-slot utilization "
          f"{stats['node_slot_utilization'] * 100:.0f}%, "
          f"{stats['partitioned_served']} oversize via partitioned mesh, "
          f"{stats['fallback_served']} oversize via padded fallback, "
          f"{stats['rejected_oversize']} rejected oversize, "
          f"{stats['rejected_invalid']} rejected invalid){err_txt}")
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--gnn", action="store_true",
                    help="serve packed GraphBatch GNN inference")
    ap.add_argument("--conv", default="gcn",
                    choices=list(Cv.CONV_TYPES))
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--oversize-requests", type=int, default=0,
                    help="append N giant graphs (~2x the node budget) to "
                         "the --gnn queue to exercise the oversize "
                         "lifecycle: partitioned SPMD program on a >= "
                         "2-device mesh, padded per-graph oracle "
                         "otherwise (docs/SERVING.md)")
    ap.add_argument("--batch-graphs", type=int, default=32)
    ap.add_argument("--agg-backend", default="xla",
                    choices=["xla", "pallas"],
                    help="segment-aggregation backend for --gnn serving "
                         "(pallas = fused edge-block kernel, single-device)")
    ap.add_argument("--dataflow", default="auto",
                    choices=["auto", "aggregate_first", "transform_first"],
                    help="transform/aggregate ordering for linear convs "
                         "(auto = per-layer cost model)")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="PrecisionPolicy datapath for --gnn serving "
                         "(low-precision tiles, fp32 accumulation; int8 "
                         "grids calibrated on the warmup batch)")
    ap.add_argument("--scheduler", default="wave",
                    choices=["wave", "continuous"],
                    help="--gnn queue discipline: 'wave' drains the whole "
                         "queue through synchronous packed waves (offline "
                         "throughput baseline); 'continuous' replays it as "
                         "an open-loop Poisson arrival process through the "
                         "continuous-batching scheduler "
                         "(runtime.scheduler, docs/SERVING.md)")
    ap.add_argument("--load", type=float, default=512.0,
                    help="offered load in graphs/s for --scheduler "
                         "continuous (open-loop Poisson arrivals)")
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="max queue wait before a partially-filled batch "
                         "launches (--scheduler continuous; the "
                         "latency/throughput knob)")
    ap.add_argument("--queue-depth", type=int, default=1024,
                    help="pending-queue bound for --scheduler continuous; "
                         "admissions beyond it are rejected (backpressure)")
    ap.add_argument("--launch-timeout-ms", type=float, default=0.0,
                    help="per-launch virtual-time bound for --scheduler "
                         "continuous: a launch not complete within it "
                         "fails as a hang and its requests re-pack onto "
                         "healthy lanes (0 = disabled)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="failed-launch re-pack attempts per request for "
                         "--scheduler continuous before the explicit "
                         "dead-letter 'failed' status")
    ap.add_argument("--shards", type=int, default=1,
                    help="data-parallel device shards for --gnn serving: "
                         "the queue drains into per-device packed shard "
                         "waves over a ('data',) mesh (needs >= N "
                         "devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count)")
    args = ap.parse_args()

    if args.gnn:
        gnn_main(args)
        return

    cfg = get_config(args.arch, reduced=args.reduced)
    plan = lm.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    b, pl_, total = args.batch, args.prompt_len, args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, pl_)), jnp.int32)
    mem = None
    if cfg.family == "vlm":
        mem = jnp.zeros((b, cfg.num_mem_tokens, cfg.mem_dim), jnp.bfloat16)
    if cfg.family == "audio":
        mem = jnp.zeros((b, total, cfg.d_model), jnp.bfloat16)

    mem_len = total if cfg.family == "audio" else cfg.num_mem_tokens
    cplan = lm.cache_plan(cfg, b, total, mem_len=mem_len)
    caches = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), prm.abstract(cplan))

    logits, pref_caches = jax.jit(
        lambda p, ids: lm.prefill(p, cfg, ids, mem))(params, prompts)
    caches = pad_caches(pref_caches, caches)

    decode = jax.jit(
        lambda p, c, ids, pos: lm.decode_step(p, cfg, c, ids, pos),
        donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen):
        logits, caches = decode(params, caches, tok, jnp.int32(pl_ + i))
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"arch={cfg.name} generated {gen.shape} tokens "
          f"({args.gen * b / dt:.1f} tok/s total, "
          f"{dt / args.gen * 1e3:.1f} ms/step)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
