"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 200 --batch 8 --seq 128

Full configs target the production mesh (use dryrun.py for lowering
proofs); --reduced runs a real ~small-scale training on the host devices
with checkpointing, resume, and fault tolerance active.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.data.pipeline import TokenDataConfig, token_batch
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.nn import param as prm
from repro.models import lm
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def build_batch_fn(cfg, seq: int, batch: int):
    data_cfg = TokenDataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                               global_batch=batch)

    def fn(step: int) -> dict:
        b = token_batch(data_cfg, step)
        b.pop("mask", None)   # train-step specs carry tokens/labels (+mem)
        if cfg.family == "vlm":
            b["mem"] = np.zeros((batch, cfg.num_mem_tokens, cfg.mem_dim),
                                np.float32)
        if cfg.family == "audio":
            rng = np.random.default_rng(step)
            b = {"tokens": b["tokens"][:, :seq // cfg.dec_len_ratio],
                 "labels": b["labels"][:, :seq // cfg.dec_len_ratio],
                 "mem": rng.standard_normal(
                     (batch, seq, cfg.d_model)).astype(np.float32)}
        return b

    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure (tests the restart path)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_host_mesh()
    bundle = steps_mod.make_train_step(
        cfg, mesh,
        opt_cfg=adamw.OptConfig(peak_lr=args.lr, warmup_steps=10,
                                decay_steps=args.steps),
        seq=args.seq, batch=args.batch)
    step_fn = bundle.jit()

    plan = lm.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    opt_state = prm.materialize(adamw.opt_plan(plan), jax.random.key(1))
    print(f"arch={cfg.name} params={prm.count_params(plan):,} "
          f"devices={len(jax.devices())}")

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir),
        step_fn, build_batch_fn(cfg, args.seq, args.batch),
        params, opt_state, fail_at_step=args.fail_at)
    result = trainer.run()
    print(f"done: {result['final_step']} steps, "
          f"loss {result['losses'][0]:.4f} -> {result['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
