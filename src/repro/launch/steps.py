"""Step builders: specialized train / prefill / decode programs per
(arch x shape x mesh x rules) — the TPU analogue of GNNBuilder's generated
accelerators. Each builder returns the pure step fn plus abstract inputs
and shardings, so callers can ``jit(...).lower(...).compile()`` without
allocating anything (dry-run) or materialize and run (examples/tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.common import SHAPES
from repro.distributed import sharding as shd
from repro.models import lm
from repro.nn import param as prm
from repro.optim import adamw


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    abstract_args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jit().lower(*self.abstract_args)


def make_constrain(mesh, rules):
    return lambda x, axes: shd.constrain(x, mesh, axes, rules)


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def _batch_io_specs(cfg: lm.LMConfig, seq: int, batch: int, mesh, rules):
    """Abstract train/prefill batch + shardings for each arch family."""
    bspec = shd.spec_for(("batch", None), (batch, seq), mesh, rules)
    sds = jax.ShapeDtypeStruct
    if cfg.family == "audio":
        dec = seq // cfg.dec_len_ratio
        bspec_d = shd.spec_for(("batch", None), (batch, dec), mesh, rules)
        mspec = shd.spec_for(("batch", None, None),
                             (batch, seq, cfg.d_model), mesh, rules)
        batch_abs = {"tokens": sds((batch, dec), jnp.int32),
                     "labels": sds((batch, dec), jnp.int32),
                     "mem": sds((batch, seq, cfg.d_model), jnp.bfloat16)}
        batch_sh = {"tokens": _named(mesh, bspec_d),
                    "labels": _named(mesh, bspec_d),
                    "mem": _named(mesh, mspec)}
    elif cfg.family == "vlm":
        mshape = (batch, cfg.num_mem_tokens, cfg.mem_dim)
        mspec = shd.spec_for(("batch", None, None), mshape, mesh, rules)
        batch_abs = {"tokens": sds((batch, seq), jnp.int32),
                     "labels": sds((batch, seq), jnp.int32),
                     "mem": sds(mshape, jnp.bfloat16)}
        batch_sh = {"tokens": _named(mesh, bspec),
                    "labels": _named(mesh, bspec),
                    "mem": _named(mesh, mspec)}
    else:
        batch_abs = {"tokens": sds((batch, seq), jnp.int32),
                     "labels": sds((batch, seq), jnp.int32)}
        batch_sh = {"tokens": _named(mesh, bspec),
                    "labels": _named(mesh, bspec)}
    return batch_abs, batch_sh


def make_train_step(cfg: lm.LMConfig, mesh, rules=None,
                    opt_cfg: adamw.OptConfig | None = None,
                    seq: int = 4096, batch: int = 256) -> StepBundle:
    rules = rules or shd.DEFAULT_RULES
    cons = make_constrain(mesh, rules)
    plan = lm.model_plan(cfg)
    if opt_cfg is None:
        # >=100B params: bf16 Adam moments (fp32 state would not fit HBM)
        big = prm.count_params(plan) >= 100e9
        opt_cfg = adamw.OptConfig(
            moment_dtype="bfloat16" if big else "float32")
    oplan = adamw.opt_plan(plan, opt_cfg)
    accum = max(1, cfg.grad_accum)

    def micro_grads(params, micro):
        def loss_of(p):
            return lm.loss_fn(p, cfg, micro, constrain=cons,
                              sync_grads=True)
        return jax.value_and_grad(loss_of)(params)

    def train_step(params, opt_state, batch_data):
        if accum == 1:
            loss, grads = micro_grads(params, batch_data)
        else:
            # microbatched gradient accumulation: activations shrink by
            # `accum`, gradients accumulate in their (sharded) storage.
            micros = jax.tree_util.tree_map(
                lambda a: a.reshape(accum, a.shape[0] // accum,
                                    *a.shape[1:]), batch_data)

            def body(carry, micro):
                loss_sum, gsum = carry
                loss, g = micro_grads(params, micro)
                gsum = jax.tree_util.tree_map(
                    lambda acc, gi: acc + gi.astype(acc.dtype), gsum, g)
                return (loss_sum + loss, gsum), None

            acc_dt = jnp.dtype(opt_cfg.moment_dtype)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micros)
            loss = loss_sum / accum
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        new_params, new_state, metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return new_params, new_state, dict(metrics, loss=loss)

    batch_abs, batch_sh = _batch_io_specs(cfg, seq, batch, mesh, rules)
    p_sh = shd.plan_shardings(plan, mesh, rules)
    o_sh = shd.plan_shardings(oplan, mesh, rules)
    return StepBundle(
        name=f"{cfg.name}:train", fn=train_step,
        abstract_args=(prm.abstract(plan), prm.abstract(oplan), batch_abs),
        in_shardings=(p_sh, o_sh, batch_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1))


def make_prefill_step(cfg: lm.LMConfig, mesh, rules=None, seq: int = 32768,
                      batch: int = 32) -> StepBundle:
    rules = rules or shd.DEFAULT_RULES
    cons = make_constrain(mesh, rules)
    plan = lm.model_plan(cfg)
    # prefill keeps activations; dots-only remat is the right default
    cfg = dataclasses.replace(cfg, remat="dots")

    def prefill_step(params, batch_data):
        tokens = batch_data["tokens"]
        logits, caches = lm.prefill(params, cfg, tokens,
                                    batch_data.get("mem"), constrain=cons)
        return logits, caches

    batch_abs, batch_sh = _batch_io_specs(cfg, seq, batch, mesh, rules)
    batch_abs.pop("labels")
    batch_sh.pop("labels")
    if cfg.family == "audio":   # decoder prompt length = seq // ratio
        pass
    p_sh = shd.plan_shardings(plan, mesh, rules)
    return StepBundle(
        name=f"{cfg.name}:prefill", fn=prefill_step,
        abstract_args=(prm.abstract(plan), batch_abs),
        in_shardings=(p_sh, batch_sh),
        out_shardings=None)


def make_decode_step(cfg: lm.LMConfig, mesh, rules=None, seq: int = 32768,
                     batch: int = 128, long_context: bool = False
                     ) -> StepBundle:
    rules = rules or shd.DEFAULT_RULES
    seq_axis = "long_seq" if long_context else "kv_seq"
    cons = make_constrain(mesh, rules)
    plan = lm.model_plan(cfg)
    mem_len = seq if cfg.family == "audio" else cfg.num_mem_tokens
    cplan = lm.cache_plan(cfg, batch, seq, mem_len=mem_len,
                          seq_axis=seq_axis)

    def decode_fn(params, caches, ids, pos):
        return lm.decode_step(params, cfg, caches, ids, pos, constrain=cons)

    ids_abs = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    ids_sh = _named(mesh, shd.spec_for(("batch", None), (batch, 1), mesh,
                                       rules))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    p_sh = shd.plan_shardings(plan, mesh, rules)
    c_sh = shd.plan_shardings(cplan, mesh, rules)
    return StepBundle(
        name=f"{cfg.name}:decode", fn=decode_fn,
        abstract_args=(prm.abstract(plan), prm.abstract(cplan), ids_abs,
                       pos_abs),
        in_shardings=(p_sh, c_sh, ids_sh, _named(mesh, P())),
        out_shardings=(None, c_sh),
        donate_argnums=(1,))


def make_gnn_train_step(cfg, mesh, rules=None, batch: int = 2048,
                        opt_cfg: adamw.OptConfig | None = None
                        ) -> StepBundle:
    """Distributed GNN training: graphs shard over the batch axes (the
    paper's workloads as first-class citizens of the same launcher)."""
    from repro.core import gnn_model as G
    rules = rules or shd.DEFAULT_RULES
    opt_cfg = opt_cfg or adamw.OptConfig()
    plan = G.model_plan(cfg)
    oplan = adamw.opt_plan(plan, opt_cfg)
    ds = getattr(cfg, "dataset", None)
    n, e = 600, 600
    fdim = cfg.graph_input_feature_dim
    edim = cfg.graph_input_edge_dim
    tgt = cfg.mlp_head.out_dim if cfg.mlp_head else 1

    def train_step(params, opt_state, batch_data):
        def loss_of(p):
            return G.mse_loss(p, cfg, batch_data)
        loss, grads = jax.value_and_grad(loss_of)(params)
        new_p, new_o, metrics = adamw.apply_updates(opt_cfg, params, grads,
                                                    opt_state)
        return new_p, new_o, dict(metrics, loss=loss)

    sds = jax.ShapeDtypeStruct
    batch_abs = {
        "node_feat": sds((batch, n, fdim), jnp.float32),
        "edge_index": sds((batch, e, 2), jnp.int32),
        "edge_feat": sds((batch, e, edim), jnp.float32),
        "num_nodes": sds((batch,), jnp.int32),
        "y": sds((batch, tgt), jnp.float32),
    }
    bsh = {k: _named(mesh, shd.spec_for(
        ("batch",) + (None,) * (len(v.shape) - 1), v.shape, mesh, rules))
        for k, v in batch_abs.items()}
    p_sh = shd.plan_shardings(plan, mesh, rules)
    o_sh = shd.plan_shardings(oplan, mesh, rules)
    return StepBundle(
        name=f"gnn:{cfg.gnn_conv}:train", fn=train_step,
        abstract_args=(prm.abstract(plan), prm.abstract(oplan), batch_abs),
        in_shardings=(p_sh, o_sh, bsh), out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1))


def make_step(cfg: lm.LMConfig, shape_name: str, mesh,
              rules=None) -> StepBundle:
    """(arch x shape) -> the step the assignment says that shape lowers."""
    info = SHAPES[shape_name]
    seq, batch, kind = info["seq"], info["batch"], info["kind"]
    if rules is None:
        preset = shd.auto_preset(cfg, kind, "pod" in mesh.axis_names)
        rules = shd.RULE_PRESETS[preset]
    if kind == "train":
        return make_train_step(cfg, mesh, rules, seq=seq, batch=batch)
    if kind == "prefill":
        return make_prefill_step(cfg, mesh, rules, seq=seq, batch=batch)
    return make_decode_step(cfg, mesh, rules, seq=seq, batch=batch,
                            long_context=(shape_name == "long_500k"))
