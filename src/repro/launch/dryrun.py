import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the specialized step program (steps.make_step),
``jit(...).lower(abstract_inputs).compile()`` against the production mesh,
and record:
  * memory_analysis()  — per-device bytes (proves the cell fits),
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective operand bytes parsed from the compiled HLO.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all            # every applicable cell
  python -m repro.launch.dryrun --all --multi-pod
Results append to benchmarks/results/dryrun/<cell>.json.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.common import SHAPES, applicable_shapes
from repro.configs.registry import ARCHS, get_config
from repro.distributed import hlo as hlo_mod
from repro.distributed.sharding import RULE_PRESETS
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def _mem_summary(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             rules: str = "auto", variant: str = "",
             save_hlo: bool = False, accum: int | None = None) -> dict:
    import dataclasses as _dc
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if accum is not None:
        cfg = _dc.replace(cfg, grad_accum=accum)
    rec = {"arch": arch, "shape": shape, "variant": variant or rules,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": mesh.devices.size, "rules": rules}
    t0 = time.time()
    try:
        bundle = steps_mod.make_step(
            cfg, shape, mesh,
            None if rules == "auto" else RULE_PRESETS[rules])
        lowered = bundle.lower()
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo_text = compiled.as_text()
        coll = hlo_mod.collective_stats(hlo_text)
        dots = hlo_mod.dot_stats(hlo_text)
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            flops=float(cost.get("flops", -1)),
            bytes_accessed=float(cost.get("bytes accessed", -1)),
            hlo_dot_flops=float(dots["flops"]),
            hlo_dot_bytes=float(dots["bytes"]),
            hlo_dot_count=int(dots["count"]),
            memory=_mem_summary(compiled),
            collectives={k: v for k, v in coll.items()
                         if not isinstance(v, dict) or v["count"]},
            collective_bytes=int(coll["total_bytes"]),
            collective_bytes_tpu=int(coll["tpu_total_bytes"]),
            hlo_chars=len(hlo_text),
        )
        if save_hlo:
            rec["hlo_path"] = os.path.join(
                RESULTS_DIR, f"{arch}__{shape}__{rec['mesh']}.hlo")
            with open(rec["hlo_path"], "w") as f:
                f.write(hlo_text)
    except Exception as e:  # a failing cell is a bug in the system
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def save(rec: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = (f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
            f"__{rec['variant']}.json")
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rules", default="auto",
                    choices=["auto"] + list(RULE_PRESETS))
    ap.add_argument("--variant", default="", help="perf-iteration tag")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else applicable_shapes(cfg))
        for shape in shapes:
            if shape not in applicable_shapes(cfg):
                print(f"SKIP {arch} x {shape}: inapplicable "
                      f"(see DESIGN.md shape-skip rules)")
                continue
            pods = [args.multi_pod] if not args.both_meshes \
                else [False, True]
            for mp in pods:
                cells.append((arch, shape, mp))

    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        out = os.path.join(
            RESULTS_DIR, f"{arch}__{shape}__{mesh_name}"
            f"__{args.variant or args.rules}.json")
        if args.skip_existing and os.path.exists(out):
            print(f"SKIP (cached) {arch} x {shape} x {mesh_name}")
            continue
        print(f"=== {arch} x {shape} x {mesh_name} ===", flush=True)
        rec = run_cell(arch, shape, multi_pod=mp, rules=args.rules,
                       variant=args.variant, save_hlo=args.save_hlo)
        save(rec)
        if rec["ok"]:
            print(f"  ok: compile={rec['compile_s']}s "
                  f"flops={rec['flops']:.3e} "
                  f"coll={rec['collective_bytes']:.3e}B "
                  f"mem={rec.get('memory', {})}", flush=True)
        else:
            print(f"  FAIL: {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
