"""Production mesh construction (functions only — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods x 256
    chips (pod, data, model) = 512. The dry-run launcher sets
    XLA_FLAGS=--xla_force_host_platform_device_count=512 before jax init."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_devices(devices, shape, axes) -> Mesh:
    """Build a mesh from an explicit device subset (elastic replan path)."""
    n = int(np.prod(shape))
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_data_mesh(num_shards: int) -> Mesh:
    """1-D ("data",) mesh over the first ``num_shards`` local devices —
    the sharded packed GNN inference mesh (each device consumes one
    GraphBatch shard, params replicate; gnn_model.apply_packed_sharded).
    On a CPU host, simulate devices with
    XLA_FLAGS=--xla_force_host_platform_device_count=N set before jax
    initializes."""
    devs = jax.devices()
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if len(devs) < num_shards:
        raise ValueError(
            f"need {num_shards} devices for {num_shards} shards, have "
            f"{len(devs)}; on CPU set XLA_FLAGS="
            "--xla_force_host_platform_device_count before jax starts")
    return Mesh(np.asarray(devs[:num_shards]), ("data",))


def make_host_mesh(model: int = 1, data: int | None = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    devs = jax.devices()
    data = data or (len(devs) // model)
    return Mesh(np.asarray(devs[:data * model]).reshape(data, model),
                ("data", "model"))
