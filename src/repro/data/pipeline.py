"""Deterministic, preemption-safe data pipelines.

``step -> batch`` is a pure function of (seed, step), so a restarted worker
resumes mid-epoch with zero coordination — the checkpoint only needs the
step counter. Two sources: synthetic token LM batches and synthetic
molecular graphs (QM9/MoleculeNet-like size statistics) for the GNN paper
workloads.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def token_batch(cfg: TokenDataConfig, step: int) -> dict:
    """Synthetic LM batch with a learnable structure (affine-lag sequences,
    so loss decreases measurably during example runs)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD47A]))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    base = rng.integers(0, v, size=(b, s), dtype=np.int32)
    # inject short-range structure: token[t] often = f(token[t-1])
    mult = 31 % v or 1
    lag = (base[:, :-1] * mult + 7) % v
    mask = rng.random((b, s - 1)) < 0.7
    base[:, 1:] = np.where(mask, lag, base[:, 1:])
    tokens = base
    labels = np.concatenate([base[:, 1:], base[:, :1]], axis=1)
    return {"tokens": tokens, "labels": labels,
            "mask": np.ones((b, s), np.float32)}


@dataclasses.dataclass(frozen=True)
class GraphDataConfig:
    """Synthetic molecular graphs, matched to MoleculeNet statistics."""
    num_graphs: int = 1000
    avg_nodes: int = 18          # QM9-like
    avg_degree: int = 2
    node_feat_dim: int = 9
    edge_feat_dim: int = 3
    num_targets: int = 1
    max_nodes: int = 600
    max_edges: int = 600
    seed: int = 0


@dataclasses.dataclass
class Graph:
    """Padded COO graph (static shapes for XLA)."""
    node_feat: np.ndarray        # (max_nodes, F)
    edge_index: np.ndarray       # (max_edges, 2) int32, padded with -1
    edge_feat: np.ndarray        # (max_edges, Fe)
    num_nodes: int
    num_edges: int
    y: np.ndarray                # (num_targets,)


def make_graph(cfg: GraphDataConfig, idx: int) -> Graph:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, idx]))
    n = int(np.clip(rng.poisson(cfg.avg_nodes), 4, cfg.max_nodes))
    # molecule-like: a random spanning tree + extra ring-closing edges
    parents = np.array([rng.integers(0, max(i, 1)) for i in range(1, n)])
    src = np.concatenate([np.arange(1, n), parents])
    dst = np.concatenate([parents, np.arange(1, n)])      # undirected pairs
    extra = max(0, int(n * (cfg.avg_degree - 2) / 2))
    if extra:
        a = rng.integers(0, n, extra)
        b = (a + 1 + rng.integers(0, n - 1, extra)) % n
        src = np.concatenate([src, a, b])
        dst = np.concatenate([dst, b, a])
    e = min(len(src), cfg.max_edges)
    edge_index = np.full((cfg.max_edges, 2), -1, np.int32)
    edge_index[:e, 0] = src[:e]
    edge_index[:e, 1] = dst[:e]
    node_feat = np.zeros((cfg.max_nodes, cfg.node_feat_dim), np.float32)
    node_feat[:n] = rng.standard_normal((n, cfg.node_feat_dim))
    edge_feat = np.zeros((cfg.max_edges, cfg.edge_feat_dim), np.float32)
    edge_feat[:e] = rng.standard_normal((e, cfg.edge_feat_dim))
    # a target that actually depends on the graph (degree/feature moments)
    y = np.array([node_feat[:n].mean() + 0.1 * e / max(n, 1)]
                 * cfg.num_targets, np.float32)
    return Graph(node_feat, edge_index, edge_feat, n, e, y)


def graph_dataset(cfg: GraphDataConfig) -> list:
    return [make_graph(cfg, i) for i in range(cfg.num_graphs)]


def graph_batch(cfg: GraphDataConfig, step: int, batch_size: int) -> dict:
    """Stacked padded graphs for batched training; deterministic in step."""
    idx0 = (step * batch_size) % cfg.num_graphs
    graphs = [make_graph(cfg, (idx0 + i) % cfg.num_graphs)
              for i in range(batch_size)]
    return {
        "node_feat": np.stack([g.node_feat for g in graphs]),
        "edge_index": np.stack([g.edge_index for g in graphs]),
        "edge_feat": np.stack([g.edge_feat for g in graphs]),
        "num_nodes": np.array([g.num_nodes for g in graphs], np.int32),
        "num_edges": np.array([g.num_edges for g in graphs], np.int32),
        "y": np.stack([g.y for g in graphs]),
    }


def compute_average_nodes_and_edges(dataset, round_val: bool = True):
    """Paper-API parity: gnnb.compute_average_nodes_and_edges."""
    n = float(np.mean([g.num_nodes for g in dataset]))
    e = float(np.mean([g.num_edges for g in dataset]))
    return (round(n), round(e)) if round_val else (n, e)


def compute_median_nodes_and_edges(dataset, round_val: bool = True):
    n = float(np.median([g.num_nodes for g in dataset]))
    e = float(np.median([g.num_edges for g in dataset]))
    return (round(n), round(e)) if round_val else (n, e)


def compute_average_degree(dataset):
    return float(np.mean([g.num_edges / max(g.num_nodes, 1)
                          for g in dataset]))
