"""Deterministic, preemption-safe data pipelines.

``step -> batch`` is a pure function of (seed, step), so a restarted worker
resumes mid-epoch with zero coordination — the checkpoint only needs the
step counter. Two sources: synthetic token LM batches and synthetic
molecular graphs (QM9/MoleculeNet-like size statistics) for the GNN paper
workloads. Graphs come in two execution formats: per-graph padded COO
(``Graph``/``graph_batch``) and the packed ``GraphBatch`` IR
(``pack_graphs``/``graph_batch_packed``) that fuses many graphs into one
budget-sized buffer — see DESIGN_BATCHING.md. ``shard_pack`` /
``pack_dataset(..., num_shards=)`` partition the stream one level
further into per-device shard waves for data-parallel sharded inference
over a ("data",) mesh (``gnn_model.apply_packed_sharded``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def token_batch(cfg: TokenDataConfig, step: int) -> dict:
    """Synthetic LM batch with a learnable structure (affine-lag sequences,
    so loss decreases measurably during example runs)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD47A]))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    base = rng.integers(0, v, size=(b, s), dtype=np.int32)
    # inject short-range structure: token[t] often = f(token[t-1])
    mult = 31 % v or 1
    lag = (base[:, :-1] * mult + 7) % v
    mask = rng.random((b, s - 1)) < 0.7
    base[:, 1:] = np.where(mask, lag, base[:, 1:])
    tokens = base
    labels = np.concatenate([base[:, 1:], base[:, :1]], axis=1)
    return {"tokens": tokens, "labels": labels,
            "mask": np.ones((b, s), np.float32)}


@dataclasses.dataclass(frozen=True)
class GraphDataConfig:
    """Synthetic molecular graphs, matched to MoleculeNet statistics."""
    num_graphs: int = 1000
    avg_nodes: int = 18          # QM9-like
    avg_degree: int = 2
    node_feat_dim: int = 9
    edge_feat_dim: int = 3
    num_targets: int = 1
    max_nodes: int = 600
    max_edges: int = 600
    seed: int = 0


@dataclasses.dataclass
class Graph:
    """Padded COO graph (static shapes for XLA)."""
    node_feat: np.ndarray        # (max_nodes, F)
    edge_index: np.ndarray       # (max_edges, 2) int32, padded with -1
    edge_feat: np.ndarray        # (max_edges, Fe)
    num_nodes: int
    num_edges: int
    y: np.ndarray                # (num_targets,)


def make_graph(cfg: GraphDataConfig, idx: int) -> Graph:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, idx]))
    n = int(np.clip(rng.poisson(cfg.avg_nodes), 4, cfg.max_nodes))
    # molecule-like: a random spanning tree + extra ring-closing edges
    parents = np.array([rng.integers(0, max(i, 1)) for i in range(1, n)])
    src = np.concatenate([np.arange(1, n), parents])
    dst = np.concatenate([parents, np.arange(1, n)])      # undirected pairs
    extra = max(0, int(n * (cfg.avg_degree - 2) / 2))
    if extra:
        a = rng.integers(0, n, extra)
        b = (a + 1 + rng.integers(0, n - 1, extra)) % n
        src = np.concatenate([src, a, b])
        dst = np.concatenate([dst, b, a])
    e = min(len(src), cfg.max_edges)
    edge_index = np.full((cfg.max_edges, 2), -1, np.int32)
    edge_index[:e, 0] = src[:e]
    edge_index[:e, 1] = dst[:e]
    node_feat = np.zeros((cfg.max_nodes, cfg.node_feat_dim), np.float32)
    node_feat[:n] = rng.standard_normal((n, cfg.node_feat_dim))
    edge_feat = np.zeros((cfg.max_edges, cfg.edge_feat_dim), np.float32)
    edge_feat[:e] = rng.standard_normal((e, cfg.edge_feat_dim))
    # a target that actually depends on the graph (degree/feature moments)
    y = np.array([node_feat[:n].mean() + 0.1 * e / max(n, 1)]
                 * cfg.num_targets, np.float32)
    return Graph(node_feat, edge_index, edge_feat, n, e, y)


def graph_dataset(cfg: GraphDataConfig) -> list:
    return [make_graph(cfg, i) for i in range(cfg.num_graphs)]


def graph_batch(cfg: GraphDataConfig, step: int, batch_size: int) -> dict:
    """Stacked padded graphs for batched training; deterministic in step."""
    idx0 = (step * batch_size) % cfg.num_graphs
    graphs = [make_graph(cfg, (idx0 + i) % cfg.num_graphs)
              for i in range(batch_size)]
    return {
        "node_feat": np.stack([g.node_feat for g in graphs]),
        "edge_index": np.stack([g.edge_index for g in graphs]),
        "edge_feat": np.stack([g.edge_feat for g in graphs]),
        "num_nodes": np.array([g.num_nodes for g in graphs], np.int32),
        "num_edges": np.array([g.num_edges for g in graphs], np.int32),
        "y": np.stack([g.y for g in graphs]),
    }


# ----------------------------------------------------- packed GraphBatch --
#
# Canonical execution format (DESIGN_BATCHING.md): many graphs packed into
# one flat node buffer sized by a node/edge *budget* instead of a per-graph
# worst case. Node/edge slots carry the owning graph_id; padding slots get
# graph_id == max_graphs (the segment-op overflow bucket) and edge slots are
# additionally marked with src == -1. All shapes are static, so one XLA
# program serves every batch.

def size_budget(batch_graphs: int, avg_count: float, slack: float = 1.5,
                multiple: int = 8) -> int:
    """Budget-sizing rule: slack x the expected total covers the Poisson
    tail of graph sizes; rounded up to a lane-friendly multiple."""
    raw = int(batch_graphs * avg_count * slack) + 1
    return -(-raw // multiple) * multiple


def graph_fits_budget(g: Graph, node_budget: int, edge_budget: int) -> bool:
    return g.num_nodes <= node_budget and g.num_edges <= edge_budget


def validate_graph(g: Graph) -> str | None:
    """Admission guard for externally-supplied graphs: returns ``None``
    for a well-formed ``Graph``, else a human-readable reason string.

    ``pack_graphs`` trusts its inputs — it adds the node-slot offset to
    every active edge row, so a negative or out-of-range endpoint
    silently corrupts a *neighboring* graph's rows in the packed batch,
    and a NaN feature poisons the whole launch. Serving paths
    (``launch.serve`` admission, ``SchedulerConfig.validate``) call this
    to reject such inputs explicitly (status ``rejected_invalid``)
    before they reach a batch. Checks the *active* prefixes only:
    padding rows (edge src == -1, zeroed features) are the format's own
    and are not screened."""
    nf = np.asarray(g.node_feat)
    ei = np.asarray(g.edge_index)
    ef = np.asarray(g.edge_feat)
    if nf.ndim != 2:
        return f"node_feat must be 2-D (max_nodes, F), got shape {nf.shape}"
    if ei.ndim != 2 or ei.shape[1] != 2:
        return f"edge_index must be (max_edges, 2), got shape {ei.shape}"
    if ef.ndim != 2:
        return f"edge_feat must be 2-D (max_edges, Fe), got shape {ef.shape}"
    if ef.shape[0] != ei.shape[0]:
        return (f"edge_feat has {ef.shape[0]} rows but edge_index has "
                f"{ei.shape[0]}")
    n, e = int(g.num_nodes), int(g.num_edges)
    if not 0 <= n <= nf.shape[0]:
        return (f"num_nodes={n} outside [0, {nf.shape[0]}] "
                "(node_feat rows)")
    if not 0 <= e <= ei.shape[0]:
        return (f"num_edges={e} outside [0, {ei.shape[0]}] "
                "(edge_index rows)")
    active = ei[:e]
    if active.size and (active.min() < 0 or active.max() >= n):
        bad = int(np.argmax((active < 0).any(1) | (active >= n).any(1)))
        return (f"edge {bad} endpoints {tuple(int(v) for v in active[bad])} "
                f"out of range for num_nodes={n}")
    if not np.isfinite(nf[:n]).all():
        return "non-finite node features in the active prefix"
    if not np.isfinite(ef[:e]).all():
        return "non-finite edge features in the active prefix"
    return None


def empty_graph_batch(node_budget: int, edge_budget: int, max_graphs: int,
                      node_feat_dim: int, edge_feat_dim: int,
                      num_targets: int = 1) -> dict:
    """All-padding GraphBatch (``num_graphs == 0``) in the standard
    layout: node/edge slots in the overflow bucket (graph_id ==
    max_graphs, edge src == -1), no valid graphs. This is what an idle
    shard of a sharded wave consumes — every device of the mesh must see
    identical static shapes, graphs or not."""
    return {"node_feat": np.zeros((node_budget, node_feat_dim), np.float32),
            "node_graph_id": np.full((node_budget,), max_graphs, np.int32),
            "edge_index": np.full((edge_budget, 2), -1, np.int32),
            "edge_feat": np.zeros((edge_budget, edge_feat_dim), np.float32),
            "edge_graph_id": np.full((edge_budget,), max_graphs, np.int32),
            "graph_valid": np.zeros((max_graphs,), bool),
            "graph_num_nodes": np.zeros((max_graphs,), np.int32),
            "num_graphs": np.int32(0),
            "y": np.zeros((max_graphs, num_targets), np.float32)}


def pack_graphs(graphs, node_budget: int, edge_budget: int,
                max_graphs: int) -> tuple:
    """Greedily pack a prefix of ``graphs`` into one GraphBatch dict.

    Packing stops at the first graph that would overflow a budget (or at
    ``max_graphs``), keeping dataset order so output row i corresponds to
    graphs[i]. Returns (batch, n_packed). Raises ValueError if graphs[0]
    alone exceeds the budget — the caller must drop or resize.
    """
    if not graphs:
        raise ValueError("pack_graphs needs at least one graph")
    if not graph_fits_budget(graphs[0], node_budget, edge_budget):
        raise ValueError(
            f"graph with {graphs[0].num_nodes} nodes/"
            f"{graphs[0].num_edges} edges exceeds budget "
            f"({node_budget} nodes/{edge_budget} edges)")
    batch = empty_graph_batch(node_budget, edge_budget, max_graphs,
                              graphs[0].node_feat.shape[1],
                              graphs[0].edge_feat.shape[1],
                              graphs[0].y.shape[0])
    n_used = e_used = k = 0
    for g in graphs:
        if k == max_graphs or n_used + g.num_nodes > node_budget \
                or e_used + g.num_edges > edge_budget:
            break
        n, e = g.num_nodes, g.num_edges
        batch["node_feat"][n_used:n_used + n] = g.node_feat[:n]
        batch["node_graph_id"][n_used:n_used + n] = k
        batch["edge_index"][e_used:e_used + e] = g.edge_index[:e] + n_used
        batch["edge_feat"][e_used:e_used + e] = g.edge_feat[:e]
        batch["edge_graph_id"][e_used:e_used + e] = k
        batch["y"][k] = g.y
        batch["graph_valid"][k] = True
        batch["graph_num_nodes"][k] = n
        n_used += n
        e_used += e
        k += 1
    batch["num_graphs"] = np.int32(k)
    return batch, k


# ------------------------------------------------------ sharded packing --
#
# Data-parallel execution across a ("data",) device mesh: one *wave* is
# num_shards GraphBatch shards with identical static shapes, one per
# device, run by a single SPMD program (gnn_model.apply_packed_sharded).
# The partitioner below is the graph-level analogue of GNNBuilder's
# parallelization factors one level up: instead of splitting a matmul
# over MAC lanes, it splits the request stream over devices.

@dataclasses.dataclass
class ShardedBatch:
    """One wave of per-device packed shards.

    ``shards`` holds ``num_shards`` GraphBatch dicts with identical
    static shapes (idle shards are ``empty_graph_batch``).
    ``index[s][j]`` is the wave-relative position of the graph packed
    into shard ``s`` row ``j`` — a permutation of range(n_graphs), so
    ``gather_shard_outputs`` can restore host order after the per-device
    outputs come back stacked."""
    shards: list
    index: list

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def n_graphs(self) -> int:
        return sum(len(ix) for ix in self.index)


def shard_pack(graphs, node_budget: int, edge_budget: int, max_graphs: int,
               num_shards: int) -> tuple:
    """Partition a prefix of ``graphs`` into ``num_shards`` per-device
    packed shards under the same *per-shard* node/edge budgets.

    Greedy least-loaded: each graph lands in the shard with the fewest
    used node slots that can still take it, so shards stay balanced
    while each shard's internal order follows the stream. Stops at the
    first graph no shard can accept (budgets or max_graphs bind).
    Returns (ShardedBatch, n_consumed); the consumed prefix is assigned
    exhaustively — every one of the first n_consumed graphs rides some
    shard. Raises ValueError if graphs[0] cannot fit an empty shard
    (the caller must drop or resize, as with pack_graphs)."""
    if not graphs:
        raise ValueError("shard_pack needs at least one graph")
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if not graph_fits_budget(graphs[0], node_budget, edge_budget):
        raise ValueError(
            f"graph with {graphs[0].num_nodes} nodes/"
            f"{graphs[0].num_edges} edges exceeds the per-shard budget "
            f"({node_budget} nodes/{edge_budget} edges)")
    assign: list = [[] for _ in range(num_shards)]
    used_n = [0] * num_shards
    used_e = [0] * num_shards
    k = 0
    for pos, g in enumerate(graphs):
        cands = [s for s in range(num_shards)
                 if len(assign[s]) < max_graphs
                 and used_n[s] + g.num_nodes <= node_budget
                 and used_e[s] + g.num_edges <= edge_budget]
        if not cands:
            break
        s = min(cands, key=lambda s: (used_n[s], used_e[s], s))
        assign[s].append(pos)
        used_n[s] += g.num_nodes
        used_e[s] += g.num_edges
        k += 1
    f = graphs[0].node_feat.shape[1]
    fe = graphs[0].edge_feat.shape[1]
    t = graphs[0].y.shape[0]
    shards = []
    for s in range(num_shards):
        if assign[s]:
            batch, _ = pack_graphs([graphs[i] for i in assign[s]],
                                   node_budget, edge_budget, max_graphs)
        else:
            batch = empty_graph_batch(node_budget, edge_budget, max_graphs,
                                      f, fe, t)
        shards.append(batch)
    return ShardedBatch(shards, assign), k


def gather_shard_outputs(outs, index) -> np.ndarray:
    """Stacked per-shard graph outputs (num_shards, max_graphs, ...) ->
    wave host order (n_graphs, ...), inverting a ShardedBatch's
    ``index`` permutation. Graph tasks only — node-task outputs are
    per-shard packed node tables with no global row order to restore."""
    outs = np.asarray(outs)
    n = sum(len(ix) for ix in index)
    host = np.zeros((n,) + outs.shape[2:], outs.dtype)
    for s, ix in enumerate(index):
        for j, pos in enumerate(ix):
            host[pos] = outs[s, j]
    return host


def pack_dataset(graphs, node_budget: int, edge_budget: int,
                 max_graphs: int, num_shards: int = 1) -> tuple:
    """Pack an entire dataset into a list of GraphBatch dicts.

    Graphs that can never fit the budget on their own are returned in
    ``dropped`` instead of stalling the stream. Order is preserved:
    concatenating the valid rows of each batch visits the non-dropped
    graphs in dataset order.

    With ``num_shards > 1`` the batches are ShardedBatch *waves*
    instead: each wave carries ``num_shards`` per-device shards under
    the same per-shard budgets (``shard_pack``), and concatenating the
    waves' ``gather_shard_outputs`` results visits the non-dropped
    graphs in dataset order.
    """
    batches, dropped = [], []
    i = 0
    while i < len(graphs):
        if not graph_fits_budget(graphs[i], node_budget, edge_budget):
            dropped.append(graphs[i])
            i += 1
            continue
        if num_shards > 1:
            wave, k = shard_pack(graphs[i:], node_budget, edge_budget,
                                 max_graphs, num_shards)
            batches.append(wave)
        else:
            batch, k = pack_graphs(graphs[i:], node_budget, edge_budget,
                                   max_graphs)
            batches.append(batch)
        i += k
    return batches, dropped


# ------------------------------------------------- intra-graph partition --
#
# Giant-graph partitioned inference: one graph larger than the packed
# node/edge budgets is split into per-device subgraphs under the same
# per-shard budgets, each carrying a *halo* — replicated boundary-node
# rows plus a fixed-shape exchange index — so that between
# message-passing layers the devices swap updated halo features over the
# ("data",) mesh (gnn_model.apply_packed_partitioned). Edge ownership
# follows the destination: the owner of an edge's dst holds the edge, so
# every aggregation is computed entirely on one device and only node
# *rows* cross the mesh. The exchange is all-gather-of-boundary-rows
# (point-to-point later); comm volume is what the DSE's `partition` axis
# prices (convs.halo_comm_bytes).

#: batch keys carried only by partitioned per-device batches (consumed
#: by the SPMD wrapper, not by apply_packed itself)
PARTITION_HALO_KEYS = ("halo_send", "halo_recv_src", "halo_recv_dst",
                       "node_global_id", "total_nodes")


@dataclasses.dataclass
class GraphPartition:
    """One oversize graph split into ``num_parts`` per-device subgraphs.

    ``parts`` holds per-device GraphBatch dicts (``max_graphs == 1``,
    identical static shapes) with the standard packed layout — owned
    rows first, then halo rows, then padding — plus the partition-only
    keys: ``node_in_deg``/``node_out_deg`` (true *global* degrees, so
    GCN normalization is exact even for halo sources whose in-edges
    live on their owner), ``node_global_id`` (reassembly scatter index,
    out-of-range sentinel on halo/padding rows),
    ``halo_send`` (owned local rows to publish, -1 pad),
    ``halo_recv_src`` (index into the (P*halo_budget, F) all-gathered
    publish buffer), ``halo_recv_dst`` (local halo row to overwrite,
    sentinel ``node_budget`` pad) and ``total_nodes``."""
    parts: list
    num_parts: int
    total_nodes: int
    total_edges: int
    cut_edges: int
    halo_nodes: int          # total replicated boundary rows across parts
    node_budget: int
    edge_budget: int
    halo_budget: int
    #: row count of the source graph's padded node buffer — the
    #: reassembly buffer is sized to it so partitioned pooling reduces
    #: over the exact same shape as the padded oracle (bitwise parity)
    padded_nodes: int = 0

    def comm_bytes(self, feat_dim: int, bytes_per_value: float,
                   num_layers: int) -> float:
        """Modeled exchange volume: edge-cut x feature bytes per layer
        boundary (the DSE comm-cost term, convs.halo_comm_bytes)."""
        return (float(self.cut_edges) * float(feat_dim)
                * float(bytes_per_value) * max(num_layers - 1, 0))


def partition_graph(g: Graph, num_parts: int, node_budget: int,
                    edge_budget: int, halo_budget: int | None = None
                    ) -> GraphPartition:
    """Greedy edge-cut partition of one graph into ``num_parts``
    per-device subgraphs under the per-shard budgets.

    Nodes are streamed in BFS order (lowest unvisited id seeds each
    component) and assigned to the part holding most of their
    already-assigned neighbors (LDG-style greedy, capacity
    ``ceil(n / num_parts)``; ties go to the least-loaded part). BFS
    order makes the greedy fill each part with one connected region,
    so the cut is the BFS frontier at each capacity boundary rather
    than a random bisection of every edge. Each edge is owned by the owner of its
    *destination*, so a destination's full in-neighborhood reduces on
    one device and only boundary-node rows are exchanged. Raises
    ``ValueError`` when any part would exceed a budget (owned + halo
    rows > node_budget, owned edges > edge_budget, or boundary rows >
    halo_budget) — the caller falls back to the padded oracle."""
    if num_parts < 1:
        raise ValueError(f"num_parts must be >= 1, got {num_parts}")
    if halo_budget is None:
        halo_budget = node_budget
    n, e = int(g.num_nodes), int(g.num_edges)
    src = np.asarray(g.edge_index[:e, 0], np.int64)
    dst = np.asarray(g.edge_index[:e, 1], np.int64)
    # -- greedy LDG node assignment -------------------------------------
    own_cap = max(-(-n // num_parts), 1)
    owner = np.full((n,), -1, np.int64)
    owned_count = np.zeros((num_parts,), np.int64)
    neighbors: list = [[] for _ in range(n)]
    for s, d in zip(src, dst):
        neighbors[s].append(int(d))
        neighbors[d].append(int(s))
    order: list = []
    visited = np.zeros((n,), bool)
    for seed in range(n):
        if visited[seed]:
            continue
        visited[seed] = True
        frontier = [seed]
        while frontier:
            v = frontier.pop(0)
            order.append(v)
            for u in sorted(set(neighbors[v])):
                if not visited[u]:
                    visited[u] = True
                    frontier.append(u)
    for v in order:
        score = np.zeros((num_parts,), np.int64)
        for u in neighbors[v]:
            if owner[u] >= 0:
                score[owner[u]] += 1
        score[owned_count >= own_cap] = -1
        cands = np.flatnonzero(score == score.max())
        p = int(min(cands, key=lambda c: (owned_count[c], c)))
        owner[v] = p
        owned_count[p] += 1
    # -- edge ownership + halo sets -------------------------------------
    edge_owner = owner[dst] if e else np.zeros((0,), np.int64)
    cut = int(np.sum(owner[src] != owner[dst])) if e else 0
    indeg = np.bincount(dst, minlength=n).astype(np.float32) if n else \
        np.zeros((0,), np.float32)
    outdeg = np.bincount(src, minlength=n).astype(np.float32) if n else \
        np.zeros((0,), np.float32)
    owned_nodes = [np.flatnonzero(owner == p) for p in range(num_parts)]
    edge_rows = [np.flatnonzero(edge_owner == p) for p in range(num_parts)]
    halo_nodes = []
    for p in range(num_parts):
        rows = edge_rows[p]
        remote = src[rows][owner[src[rows]] != p] if rows.size else \
            np.zeros((0,), np.int64)
        halo_nodes.append(np.unique(remote))
    send_nodes = []
    for p in range(num_parts):
        needed = [h[owner[h] == p] for h in halo_nodes]
        send_nodes.append(np.unique(np.concatenate(needed)) if needed
                          else np.zeros((0,), np.int64))
    for p in range(num_parts):
        n_own, n_halo = len(owned_nodes[p]), len(halo_nodes[p])
        if n_own + n_halo > node_budget:
            raise ValueError(
                f"part {p}: {n_own} owned + {n_halo} halo rows exceed "
                f"node_budget {node_budget}")
        if len(edge_rows[p]) > edge_budget:
            raise ValueError(
                f"part {p}: {len(edge_rows[p])} owned edges exceed "
                f"edge_budget {edge_budget}")
        if max(n_halo, len(send_nodes[p])) > halo_budget:
            raise ValueError(
                f"part {p}: {max(n_halo, len(send_nodes[p]))} boundary "
                f"rows exceed halo_budget {halo_budget}")
    # -- per-part batches ------------------------------------------------
    f = g.node_feat.shape[1]
    fe = g.edge_feat.shape[1]
    t = g.y.shape[0]
    # out-of-range for any reassembly buffer: the drop-mode scatter
    # ignores halo/padding rows no matter how the buffer is sized
    gid_sentinel = np.int32(2 ** 30)
    # global node id -> (part-local send position) for recv_src lookup
    send_pos = {}
    for p in range(num_parts):
        for j, v in enumerate(send_nodes[p]):
            send_pos[int(v)] = p * halo_budget + j
    parts = []
    for p in range(num_parts):
        own = owned_nodes[p]
        halo = halo_nodes[p]
        n_own, n_halo = len(own), len(halo)
        local = np.full((max(n, 1),), -1, np.int64)
        local[own] = np.arange(n_own)
        local[halo] = n_own + np.arange(n_halo)
        batch = empty_graph_batch(node_budget, edge_budget, 1, f, fe, t)
        batch["node_feat"][:n_own] = g.node_feat[own]
        batch["node_feat"][n_own:n_own + n_halo] = g.node_feat[halo]
        batch["node_graph_id"][:n_own + n_halo] = 0
        rows = edge_rows[p]
        ne = len(rows)
        batch["edge_index"][:ne, 0] = local[src[rows]]
        batch["edge_index"][:ne, 1] = local[dst[rows]]
        batch["edge_feat"][:ne] = g.edge_feat[rows]
        batch["edge_graph_id"][:ne] = 0
        batch["graph_valid"][0] = True
        batch["graph_num_nodes"][0] = n_own + n_halo
        batch["num_graphs"] = np.int32(1)
        batch["y"][0] = g.y
        # true global degrees for every active local row (owned + halo)
        deg_in = np.zeros((node_budget,), np.float32)
        deg_out = np.zeros((node_budget,), np.float32)
        deg_in[:n_own] = indeg[own]
        deg_in[n_own:n_own + n_halo] = indeg[halo]
        deg_out[:n_own] = outdeg[own]
        deg_out[n_own:n_own + n_halo] = outdeg[halo]
        batch["node_in_deg"] = deg_in
        batch["node_out_deg"] = deg_out
        gid = np.full((node_budget,), gid_sentinel, np.int32)
        gid[:n_own] = own
        batch["node_global_id"] = gid
        hs = np.full((halo_budget,), -1, np.int32)
        hs[:len(send_nodes[p])] = local[send_nodes[p]]
        batch["halo_send"] = hs
        hr_src = np.zeros((halo_budget,), np.int32)
        hr_dst = np.full((halo_budget,), node_budget, np.int32)
        for j, v in enumerate(halo):
            hr_src[j] = send_pos[int(v)]
            hr_dst[j] = n_own + j
        batch["halo_recv_src"] = hr_src
        batch["halo_recv_dst"] = hr_dst
        batch["total_nodes"] = np.int32(n)
        parts.append(batch)
    return GraphPartition(
        parts=parts, num_parts=num_parts, total_nodes=n, total_edges=e,
        cut_edges=cut, halo_nodes=int(sum(len(h) for h in halo_nodes)),
        node_budget=node_budget, edge_budget=edge_budget,
        halo_budget=halo_budget, padded_nodes=int(g.node_feat.shape[0]))


def graph_batch_packed(cfg: GraphDataConfig, step: int, node_budget: int,
                       edge_budget: int, max_graphs: int) -> dict:
    """Deterministic step-indexed packed batch: the candidate window is
    the ``max_graphs`` dataset indices starting at step * max_graphs
    (mod dataset size), packed greedily until a budget binds. Pure in
    (cfg.seed, step) — a restarted worker rebuilds the identical batch.

    When a budget binds before the window is exhausted, the tail graphs
    of that window are skipped for this step. The start index rotates by
    one extra slot per epoch, so window boundaries shift across epochs
    and a skipped tail is packed on a later pass — no graph is
    *permanently* excluded, even when max_graphs divides num_graphs.
    """
    epoch = (step * max_graphs) // cfg.num_graphs
    idx0 = (step * max_graphs + epoch) % cfg.num_graphs
    graphs = [make_graph(cfg, (idx0 + i) % cfg.num_graphs)
              for i in range(max_graphs)]
    batch, _ = pack_graphs(graphs, node_budget, edge_budget, max_graphs)
    return batch


def compute_average_nodes_and_edges(dataset, round_val: bool = True):
    """Paper-API parity: gnnb.compute_average_nodes_and_edges."""
    n = float(np.mean([g.num_nodes for g in dataset]))
    e = float(np.mean([g.num_edges for g in dataset]))
    return (round(n), round(e)) if round_val else (n, e)


def compute_median_nodes_and_edges(dataset, round_val: bool = True):
    n = float(np.median([g.num_nodes for g in dataset]))
    e = float(np.median([g.num_edges for g in dataset]))
    return (round(n), round(e)) if round_val else (n, e)


def compute_average_degree(dataset):
    return float(np.mean([g.num_edges / max(g.num_nodes, 1)
                          for g in dataset]))
