"""Continuous-batching GNN serving scheduler (ROADMAP item 1).

``launch/serve.py --gnn`` historically drained its queue in synchronous
waves: collect a window of requests, pack, run, repeat — fine for
offline throughput, wrong for live traffic where a request's latency is
dominated by how long it waits for its batch to form. This module is
the event-driven replacement: requests are admitted continuously into a
partially-filled packed batch under the GraphBatch node/edge budgets,
and a launch policy fires the batch on **deadline expiry** (the oldest
pending request has waited its SLO tier's ``deadline_s``) or
**budget-full** (max_graphs reached, or the node/edge budget blocks a
pending request from riding) — the latency/throughput trade is exactly
that deadline knob.

Design rules:

* **Clock-injected.** The scheduler never reads wall time; it asks an
  injected clock (``VirtualClock``). Scripted arrival traces therefore
  replay bit-identically — no sleeps, no flakes
  (tests/test_scheduler.py). Real serving keeps the virtual arrival
  timeline but lets ``MeasuredExecutor`` report measured wall-seconds
  as the service time, so latency statistics are traffic-shaped while
  compute cost is real.
* **jax-free.** Execution hides behind the executor protocol
  (``run_batch``/``run_fallback`` -> (outputs, service_s)); the
  scheduler itself only packs and keeps time, so the DSE can simulate
  thousands of traffic scenarios (``dse.explore(objective=
  "p99_latency")``) without touching a device.
* **Explicit rejection.** Pending queues are bounded
  (``max_queue_depth`` per tenant); an admission that would exceed the
  bound is rejected immediately (``rejected_queue_full``) instead of
  buffered without bound. Oversize requests ride the partitioned SPMD
  program when a lane has a >= 2-device mesh behind it
  (``run_partitioned`` -> ``served_partitioned``), the padded per-graph
  fallback when only that exists (``served_fallback``), else they are
  rejected (``rejected_oversize``); malformed inputs are rejected at admission
  (``rejected_invalid``, via ``data.pipeline.validate_graph`` when
  ``SchedulerConfig.validate`` is set) — never silently dropped.
* **Fault tolerance.** An executor exception, hung launch, or
  NaN/Inf-corrupted output must never crash the serving loop or lose a
  request. A failed launch's requests re-pack **exactly once each**
  onto healthy lanes with capped exponential backoff, and after
  ``max_retries`` re-pack attempts a request resolves to the explicit
  dead-letter status ``failed`` — every submitted request ends in
  exactly one terminal status, under any fault plan
  (``runtime.faults`` is the deterministic injection harness).
* **Lane health.** Per-lane service times ride
  ``runtime.straggler.StragglerDetector``, and hard launch failures
  drive the lane state machine healthy -> degraded -> quarantined ->
  (single canary probe) -> healthy. Quarantine is *temporary*: after a
  capped-exponential cooldown the lane takes exactly one probe launch
  and rejoins the pool on success. Pool shrinkage/regrowth is
  re-planned through ``runtime.elastic.pool_plan`` on every
  transition (``pool_events``). Executor-pool sizing comes from
  ``runtime.elastic.plan_mesh_shape`` (``plan_executor_pool``).

Lifecycle diagram, failure taxonomy, and knob table: docs/SERVING.md.
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.data import pipeline as P
from repro.runtime.elastic import plan_mesh_shape, pool_plan
from repro.runtime.straggler import StragglerDetector

# response statuses — every submitted request ends in exactly one of these
SERVED_PACKED = "served_packed"
SERVED_PARTITIONED = "served_partitioned"
SERVED_FALLBACK = "served_fallback"
REJECTED_QUEUE = "rejected_queue_full"
REJECTED_OVERSIZE = "rejected_oversize"
REJECTED_INVALID = "rejected_invalid"
FAILED = "failed"

# lane health states: healthy -> degraded -> quarantined -> probing -> healthy
LANE_HEALTHY = "healthy"
LANE_DEGRADED = "degraded"
LANE_QUARANTINED = "quarantined"
LANE_PROBING = "probing"

# launch failure taxonomy (docs/SERVING.md): the `status` a failed
# launch records and the `reason` its lane-health events carry
FAIL_CRASH = "crash"
FAIL_TIMEOUT = "timeout"
FAIL_NONFINITE = "nonfinite_output"


class ExecutorCrash(RuntimeError):
    """An executor failed mid-launch. ``after_s`` is how long after the
    launch the failure surfaces on the virtual timeline (0.0 = at
    launch). Executors (and the ``runtime.faults`` harness) raise this;
    any *other* exception an executor raises is handled identically
    with ``after_s = 0`` — a lane fault must never crash the serving
    loop."""

    def __init__(self, msg: str = "executor crashed", after_s: float = 0.0):
        super().__init__(msg)
        self.after_s = float(after_s)


class PartitionInfeasible(ValueError):
    """``run_partitioned`` cannot split this graph under the per-device
    budgets (e.g. one partition's owned+halo rows exceed the node
    budget). The scheduler catches it and reroutes the request to the
    padded fallback on the same launch — it is a routing signal, not a
    lane fault."""


# ------------------------------------------------------------------ clock --

class VirtualClock:
    """Injected simulation time: starts at ``t0``, only moves forward."""

    def __init__(self, t0: float = 0.0):
        self._now = float(t0)

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float):
        if t < self._now - 1e-12:
            raise ValueError(f"clock cannot run backwards: {t} < {self._now}")
        self._now = max(self._now, float(t))


# ---------------------------------------------------------------- metrics --

def percentile(values, q: float) -> float | None:
    """Nearest-rank percentile: the smallest sample whose empirical CDF
    reaches q/100 (``sorted(values)[ceil(q/100 * n) - 1]``). Chosen over
    interpolating definitions because scripted traces then have
    *closed-form* expected p50/p99 the tests can assert exactly.

    Returns ``None`` (an explicit null that survives JSON round-trips,
    unlike NaN) when ``values`` is empty — callers gate on
    ``served == 0`` before comparing percentiles."""
    s = sorted(values)
    if not s:
        return None
    k = max(1, math.ceil(q / 100.0 * len(s)))
    return float(s[min(k, len(s)) - 1])


def summarize(responses, *, fills=(), max_graphs: int = 0,
              node_budget: int = 0, nodes_used: int = 0) -> dict:
    """Latency/throughput/fill statistics over a response list. Shared by
    the continuous scheduler and the wave-drain baseline so their
    figures are directly comparable. With ``served == 0`` every latency
    figure is an explicit ``None`` (JSON null), never NaN."""
    served = [r for r in responses if r.served]
    lat = [r.latency_s for r in served]
    by_status: dict = {}
    for r in responses:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    t0 = min((r.arrival_s for r in served), default=0.0)
    t1 = max((r.complete_s for r in served), default=0.0)
    tenants = sorted({r.tenant for r in responses})
    per_tenant = {}
    for t in tenants:
        tl = [r.latency_s for r in served if r.tenant == t]
        per_tenant[t] = {
            "served": len(tl),
            "rejected": sum(1 for r in responses
                            if r.tenant == t and not r.served),
            "p50_latency_s": percentile(tl, 50),
            "p99_latency_s": percentile(tl, 99),
        }
    n_packed = len(fills)
    return {
        "served": len(served),
        "packed_served": by_status.get(SERVED_PACKED, 0),
        "partitioned_served": by_status.get(SERVED_PARTITIONED, 0),
        "fallback_served": by_status.get(SERVED_FALLBACK, 0),
        "rejected_queue_full": by_status.get(REJECTED_QUEUE, 0),
        "rejected_oversize": by_status.get(REJECTED_OVERSIZE, 0),
        "rejected_invalid": by_status.get(REJECTED_INVALID, 0),
        "failed": by_status.get(FAILED, 0),
        "n_launches": n_packed,
        "mean_batch_fill": (sum(fills) / (n_packed * max_graphs)
                            if n_packed and max_graphs else 0.0),
        "node_slot_utilization": (nodes_used / (n_packed * node_budget)
                                  if n_packed and node_budget else 0.0),
        "p50_latency_s": percentile(lat, 50),
        "p99_latency_s": percentile(lat, 99),
        "mean_latency_s": (sum(lat) / len(lat)) if lat else None,
        "max_latency_s": max(lat) if lat else None,
        "graphs_per_s": len(served) / max(t1 - t0, 1e-12) if served else 0.0,
        "makespan_s": t1 - t0,
        "per_tenant": per_tenant,
    }


# ----------------------------------------------------- requests/responses --

@dataclasses.dataclass(frozen=True)
class SLOTier:
    """``deadline_s`` is the longest a request of this tier may wait in
    the pending queue before a launch is forced; higher ``priority``
    packs first when the budget is contended."""
    name: str
    deadline_s: float
    priority: int = 0


DEFAULT_TIER = SLOTier("standard", 0.050, 1)

#: example tenant->tier mapping used by serve.py and the benchmark
DEFAULT_TIERS = {
    "premium": SLOTier("premium", 0.010, 2),
    "standard": DEFAULT_TIER,
    "batch": SLOTier("batch", 0.500, 0),
}


@dataclasses.dataclass(eq=False)
class Request:
    req_id: int
    graph: P.Graph
    tenant: str = "default"
    arrival_s: float = 0.0
    #: failed-launch re-pack attempts consumed so far (exactly-once:
    #: a request rides at most ``1 + max_retries`` launches)
    attempts: int = 0
    #: earliest time a retried request may be packed again (capped
    #: exponential backoff from the failure time)
    not_before_s: float = 0.0


@dataclasses.dataclass(eq=False)
class Response:
    req_id: int
    tenant: str
    status: str
    arrival_s: float
    launch_s: float = float("nan")
    complete_s: float = float("nan")
    output: np.ndarray | None = None
    batch_seq: int = -1
    executor: int = -1

    @property
    def served(self) -> bool:
        return self.status in (SERVED_PACKED, SERVED_PARTITIONED,
                               SERVED_FALLBACK)

    @property
    def latency_s(self) -> float:
        return self.complete_s - self.arrival_s


# -------------------------------------------------------------- executors --

def constant_service(service_s: float):
    """A fixed-shape packed program costs the same however full the batch
    is — constant per-launch service is the honest model for it."""
    def model(n_graphs: int, n_nodes: int, n_edges: int) -> float:
        return float(service_s)
    return model


def linear_service(base_s: float, per_node_s: float = 0.0,
                   per_edge_s: float = 0.0):
    def model(n_graphs: int, n_nodes: int, n_edges: int) -> float:
        return float(base_s + per_node_s * n_nodes + per_edge_s * n_edges)
    return model


class SimExecutor:
    """Deterministic executor for simulation: service time from
    ``service_model(n_graphs, n_nodes, n_edges)``; outputs from the
    optional ``batch_fn(batch)`` / ``fallback_fn(graph)`` callables
    (real programs in parity tests and benchmarks, ``None`` in pure
    latency simulations such as the DSE objective)."""

    def __init__(self, service_model, batch_fn=None, fallback_fn=None,
                 allow_fallback: bool = True, partition_fn=None,
                 allow_partition: bool = False, num_partitions: int = 1):
        self.service_model = service_model
        self.batch_fn = batch_fn
        self.fallback_fn = fallback_fn
        self.allow_fallback = allow_fallback
        self.partition_fn = partition_fn
        self.allow_partition = allow_partition
        self.num_partitions = max(int(num_partitions), 1)

    @property
    def can_fallback(self) -> bool:
        return self.allow_fallback

    @property
    def can_partition(self) -> bool:
        return self.allow_partition or self.partition_fn is not None

    def run_batch(self, batch: dict):
        out = self.batch_fn(batch) if self.batch_fn is not None else None
        max_graphs = len(batch["graph_valid"])
        n_nodes = int((batch["node_graph_id"] < max_graphs).sum())
        n_edges = int((batch["edge_index"][:, 0] >= 0).sum())
        svc = self.service_model(int(batch["num_graphs"]), n_nodes, n_edges)
        return out, float(svc)

    def run_fallback(self, graph: P.Graph):
        out = self.fallback_fn(graph) if self.fallback_fn is not None \
            else None
        svc = self.service_model(1, graph.num_nodes, graph.num_edges)
        return out, float(svc)

    def run_partitioned(self, graph: P.Graph):
        """Partitioned oversize launch: the per-device subgraphs run
        concurrently, so the modeled service time is the service model
        over one partition's share of the graph. ``partition_fn`` (when
        set) supplies real outputs and may raise ``PartitionInfeasible``
        to reroute the request to the padded fallback."""
        out = self.partition_fn(graph) if self.partition_fn is not None \
            else None
        p = self.num_partitions
        svc = self.service_model(1, -(-graph.num_nodes // p),
                                 -(-graph.num_edges // p))
        return out, float(svc)


class MeasuredExecutor:
    """Real-execution executor: ``batch_fn``/``fallback_fn`` must block
    until their result is ready; the measured wall-seconds become the
    service time on the scheduler's virtual timeline. Arrivals stay
    scripted, so the latency statistics are traffic-shaped while the
    compute cost is the real program's. A raised exception is handled
    by the scheduler as a launch crash (retry -> dead-letter), never a
    serving-loop crash."""

    def __init__(self, batch_fn, fallback_fn=None, partition_fn=None):
        self.batch_fn = batch_fn
        self.fallback_fn = fallback_fn
        self.partition_fn = partition_fn

    @property
    def can_fallback(self) -> bool:
        return self.fallback_fn is not None

    @property
    def can_partition(self) -> bool:
        return self.partition_fn is not None

    def run_batch(self, batch: dict):
        t0 = time.perf_counter()
        out = self.batch_fn(batch)
        return out, time.perf_counter() - t0

    def run_fallback(self, graph: P.Graph):
        t0 = time.perf_counter()
        out = self.fallback_fn(graph)
        return out, time.perf_counter() - t0

    def run_partitioned(self, graph: P.Graph):
        """``partition_fn`` must block until the SPMD partitioned program
        has answered; it may raise ``PartitionInfeasible`` when the graph
        cannot split under the per-device budgets (the scheduler then
        reroutes to ``run_fallback`` on the same launch)."""
        t0 = time.perf_counter()
        out = self.partition_fn(graph)
        return out, time.perf_counter() - t0


def plan_executor_pool(n_devices: int,
                       shards_per_executor: int = 1) -> int:
    """Number of parallel launch lanes a host's devices support: the
    ``data`` axis of ``elastic.plan_mesh_shape`` with the model axis
    standing in for devices-per-executor (a sharded executor drives a
    whole shard group)."""
    shape, axes = plan_mesh_shape(n_devices, model_pref=shards_per_executor)
    return shape[axes.index("data")]


# -------------------------------------------------------------- scheduler --

@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    node_budget: int
    edge_budget: int
    max_graphs: int
    #: per-tenant pending-queue bound: admissions beyond it are rejected
    #: (backpressure), never buffered without bound. Failed-launch
    #: retries bypass the bound — they were already admitted once.
    max_queue_depth: int = 256
    #: tenant name -> SLOTier; unknown tenants get ``default_tier``
    tiers: dict | None = None
    default_tier: SLOTier = DEFAULT_TIER
    #: virtual-time bound on one launch; a launch not complete by
    #: ``launch_s + launch_timeout_s`` fails as a hang (the lane is a
    #: hard-failure suspect) and its requests re-pack. inf = no bound.
    launch_timeout_s: float = math.inf
    #: failed-launch re-pack attempts per request before the explicit
    #: dead-letter ``failed`` status (never a hang, never a silent drop)
    max_retries: int = 2
    #: capped exponential backoff before a failed request re-packs:
    #: min(retry_backoff_s * 2^(attempt-1), retry_backoff_cap_s)
    retry_backoff_s: float = 0.0
    retry_backoff_cap_s: float = 0.5
    #: consecutive hard launch failures before a lane quarantines (the
    #: first failure only degrades it)
    quarantine_after: int = 2
    #: cooldown before a quarantined lane takes its single canary probe
    #: launch; doubles per quarantine up to the cap
    quarantine_cooldown_s: float = 0.5
    quarantine_cooldown_cap_s: float = 8.0
    #: screen admissions through ``data.pipeline.validate_graph`` and
    #: reject malformed graphs explicitly (``rejected_invalid``)
    validate: bool = False
    #: devices each lane drives (feeds ``elastic.pool_plan`` replans)
    shards_per_executor: int = 1


@dataclasses.dataclass(eq=False)
class LaneHealth:
    """Per-lane health state machine (docs/SERVING.md §Fault tolerance).

    healthy -> degraded (first hard failure) -> quarantined
    (``quarantine_after`` consecutive failures, or a straggler ``evict``)
    -> probing (single canary launch once ``probe_at_s`` passes) ->
    healthy on probe success / re-quarantined with doubled cooldown on
    probe failure."""
    state: str = LANE_HEALTHY
    consecutive_failures: int = 0
    failures: int = 0            # lifetime hard-failure count
    quarantines: int = 0         # lifetime quarantine count (cooldown 2^k)
    probe_at_s: float = 0.0      # probe eligibility time while quarantined


@dataclasses.dataclass(eq=False)
class _Inflight:
    kind: str                 # "packed" | "partitioned" | "fallback"
    requests: list
    outputs: object
    launch_s: float
    done_s: float
    seq: int
    error: str | None = None  # FAIL_CRASH when the launch already failed
    probe: bool = False       # canary launch of a quarantined lane


@dataclasses.dataclass(eq=False)
class _Selection:
    requests: list            # chosen for the packed batch, pack order
    fallback: object          # head-of-order oversize Request, or None
    full: bool                # launch now regardless of deadlines


class ContinuousScheduler:
    """Event-driven continuous-batching loop over one or more executor
    lanes. Drive it with ``submit``/``tick``/``next_event_s`` (or the
    ``run_trace`` helper); read ``responses``/``summary()``."""

    def __init__(self, cfg: SchedulerConfig, executors, clock=None,
                 detector: StragglerDetector | None = None):
        if not isinstance(executors, (list, tuple)):
            executors = [executors]
        if not executors:
            raise ValueError("need at least one executor")
        self.cfg = cfg
        self.executors = list(executors)
        self.clock = clock or VirtualClock()
        self.detector = detector or StragglerDetector()
        self.pending: list = []
        self.inflight: dict = {}         # exec id -> _Inflight
        self.responses: list = []
        self.launches: list = []         # per-launch {seq, kind, req_ids, …}
        self.lanes = [LaneHealth() for _ in self.executors]
        self.events: list = []           # health/failure event log
        self.pool_events: list = []      # elastic pool replans
        self.retries = 0                 # failed-request re-packs performed
        self.failed_launches = 0
        self.probes_succeeded = 0
        self.probes_failed = 0
        self._depth: dict = {}           # tenant -> pending count
        self._next_id = 0
        self._seq = 0
        self._fills: list = []
        self._nodes_used = 0
        self._flushing = False
        self._replan_pool(self.clock.now())

    # ------------------------------------------------------------- admission
    def submit(self, graph: P.Graph, tenant: str = "default") -> int:
        """Admit (or reject) one request at the clock's current time.
        Always returns the request id; exactly one Response will
        eventually carry it. Check order: malformed input (when
        ``cfg.validate``), oversize with no fallback lane, queue bound."""
        now = self.clock.now()
        rid = self._next_id
        self._next_id += 1
        if self.cfg.validate:
            reason = P.validate_graph(graph)
            if reason is not None:
                self.responses.append(Response(rid, tenant, REJECTED_INVALID,
                                               now))
                self.events.append({"t": now, "kind": "rejected_invalid",
                                    "req_id": rid, "reason": reason})
                return rid
        fits = P.graph_fits_budget(graph, self.cfg.node_budget,
                                   self.cfg.edge_budget)
        if not fits and not (self._can_partition() or self._can_fallback()):
            self.responses.append(Response(rid, tenant, REJECTED_OVERSIZE,
                                           now))
            return rid
        if self._depth.get(tenant, 0) >= self.cfg.max_queue_depth:
            self.responses.append(Response(rid, tenant, REJECTED_QUEUE, now))
            return rid
        self.pending.append(Request(rid, graph, tenant, now))
        self._depth[tenant] = self._depth.get(tenant, 0) + 1
        self._launch_ready(now)          # budget-full may fire immediately
        return rid

    # ----------------------------------------------------------- event loop
    def next_event_s(self) -> float | None:
        """Earliest time ``tick()`` would do work: the soonest in-flight
        completion *or timeout expiry*, the earliest pending launch (now
        if budget-full or flushing, else the oldest deadline), a retry
        maturing from backoff, or an idle quarantined lane becoming
        probe-eligible. None when fully drained."""
        now = self.clock.now()
        times = [self._due_s(u) for u in self.inflight.values()]
        if self.pending:
            times += [r.not_before_s for r in self.pending
                      if r.not_before_s > now]
            unit = self._ready_unit(now)
            if unit is not None:
                sel, _ = unit
                if self._flushing or sel.full:
                    times.append(now)
                else:
                    times.append(max(self._earliest_due_s(now), now))
            else:
                # nothing launchable right now: wake when an idle
                # quarantined lane becomes probe-eligible
                times += [l.probe_at_s for i, l in enumerate(self.lanes)
                          if i not in self.inflight
                          and l.state == LANE_QUARANTINED
                          and l.probe_at_s > now]
        return min(times) if times else None

    def tick(self):
        """Process everything due at the clock's current time:
        completions/timeouts first (they free lanes), then launches."""
        now = self.clock.now()
        self._complete_due(now)
        self._launch_ready(now)

    def drain(self):
        """Flush: launch everything pending regardless of deadlines and
        run the clock forward until all lanes are idle. Terminates under
        any fault plan — retries are capped per request and quarantine
        cooldowns are finite."""
        self._flushing = True
        try:
            while True:
                t = self.next_event_s()
                if t is None:
                    break
                self.clock.advance_to(t)
                self.tick()
        finally:
            self._flushing = False

    def summary(self) -> dict:
        s = summarize(self.responses, fills=self._fills,
                      max_graphs=self.cfg.max_graphs,
                      node_budget=self.cfg.node_budget,
                      nodes_used=self._nodes_used)
        s["retries"] = self.retries
        s["failed_launches"] = self.failed_launches
        s["lane_states"] = [l.state for l in self.lanes]
        s["quarantined_executors"] = sorted(
            i for i, l in enumerate(self.lanes)
            if l.state == LANE_QUARANTINED)
        s["probes"] = {"succeeded": self.probes_succeeded,
                       "failed": self.probes_failed}
        s["pool_events"] = list(self.pool_events)
        return s

    # -------------------------------------------------------------- internal
    def _tier(self, tenant: str) -> SLOTier:
        return (self.cfg.tiers or {}).get(tenant, self.cfg.default_tier)

    def _due_s(self, u: _Inflight) -> float:
        """Time an in-flight unit resolves: completion or timeout expiry,
        whichever is sooner."""
        return min(u.done_s, u.launch_s + self.cfg.launch_timeout_s)

    def _available(self):
        """Lanes currently in the pool (not quarantined)."""
        return [i for i, l in enumerate(self.lanes)
                if l.state != LANE_QUARANTINED]

    def _launch_lane(self, sel, now: float) -> int | None:
        """Best idle lane able to run the unit right now: healthy or
        degraded lanes first (lowest index), then probe-eligible
        quarantined lanes (their launch is the canary probe). Fallback
        units need a fallback-capable executor."""
        cands = []
        for i, lane in enumerate(self.lanes):
            if i in self.inflight:
                continue
            if sel.fallback is not None and not (
                    getattr(self.executors[i], "can_partition", False)
                    or getattr(self.executors[i], "can_fallback", False)):
                continue
            if lane.state in (LANE_HEALTHY, LANE_DEGRADED):
                cands.append((0, i))
            elif lane.state == LANE_QUARANTINED \
                    and now >= lane.probe_at_s - 1e-12:
                cands.append((1, i))
        return min(cands)[1] if cands else None

    def _ready_unit(self, now: float):
        """(selection, lane) for the next launchable unit, or None. When
        the head-of-order oversize request has no idle fallback-capable
        lane, packed work behind it may still launch."""
        if not self._ready_pending(now):
            return None
        sel = self._select(now)
        lane = self._launch_lane(sel, now)
        if lane is None and sel.fallback is not None:
            sel = self._select(now, skip_head_oversize=True)
            lane = self._launch_lane(sel, now) if sel.requests else None
        if lane is None or (sel.fallback is None and not sel.requests):
            return None
        return sel, lane

    def _can_fallback(self) -> bool:
        # quarantine is temporary, so a quarantined fallback lane still
        # counts at admission — its work waits for the probe-back
        return any(getattr(e, "can_fallback", False)
                   for e in self.executors)

    def _can_partition(self) -> bool:
        """Mesh-aware oversize classification: an executor backed by a
        >= 2-device mesh advertises ``can_partition`` and answers
        oversize requests through the partitioned SPMD program
        (``served_partitioned``); the padded oracle stays as the no-mesh
        fallback (``served_fallback``). Admission and launch consult
        the same predicate, so an oversize request is classified exactly
        once — it can never end up double-counted across
        ``partitioned_served``/``fallback_served``/``rejected_oversize``."""
        return any(getattr(e, "can_partition", False)
                   for e in self.executors)

    def _oversize(self, g: P.Graph) -> bool:
        return not P.graph_fits_budget(g, self.cfg.node_budget,
                                       self.cfg.edge_budget)

    def _ready_pending(self, now: float) -> list:
        """Pending requests eligible to pack now (retry backoff
        honored)."""
        return [r for r in self.pending if r.not_before_s <= now + 1e-12]

    def _ordered_pending(self, now: float) -> list:
        return sorted(self._ready_pending(now),
                      key=lambda r: (-self._tier(r.tenant).priority,
                                     r.arrival_s, r.req_id))

    def _earliest_due_s(self, now: float) -> float:
        return min(max(r.arrival_s + self._tier(r.tenant).deadline_s,
                       r.not_before_s)
                   for r in self._ready_pending(now))

    def _select(self, now: float,
                skip_head_oversize: bool = False) -> _Selection:
        """First-fit scan of the pending queue in (priority, arrival)
        order. An oversize request at the head of the order becomes a
        dedicated fallback launch; oversize requests further back wait
        (they cannot share a batch). A fitting-class request blocked by
        the remaining budget marks the batch *full* — it re-packs into
        the next launch (the straggler rule)."""
        order = self._ordered_pending(now)
        if (not skip_head_oversize and order
                and self._oversize(order[0].graph)):
            return _Selection([], order[0], True)
        sel: list = []
        n_used = e_used = 0
        full = False
        for r in order:
            if self._oversize(r.graph):
                continue
            if len(sel) == self.cfg.max_graphs:
                full = True
                break
            if (n_used + r.graph.num_nodes <= self.cfg.node_budget
                    and e_used + r.graph.num_edges <= self.cfg.edge_budget):
                sel.append(r)
                n_used += r.graph.num_nodes
                e_used += r.graph.num_edges
            else:
                full = True
        return _Selection(sel, None, full or len(sel) == self.cfg.max_graphs)

    def _launch_ready(self, now: float):
        while True:
            unit = self._ready_unit(now)
            if unit is None:
                return
            sel, lane = unit
            due = (self._flushing or sel.full
                   or self._earliest_due_s(now) <= now)
            if not due:
                return
            self._launch(lane, sel, now)

    def _remove_pending(self, req: Request):
        self.pending.remove(req)
        self._depth[req.tenant] -= 1

    def _requeue(self, req: Request):
        """Exactly-once re-pack of a failed launch's rider: back into
        pending (bypassing the admission bound — it was admitted once)
        with its backoff-derived earliest re-pack time already set."""
        self.pending.append(req)
        self._depth[req.tenant] = self._depth.get(req.tenant, 0) + 1

    def _launch(self, exec_id: int, sel: _Selection, now: float):
        executor = self.executors[exec_id]
        lane = self.lanes[exec_id]
        probe = lane.state == LANE_QUARANTINED
        if probe:
            lane.state = LANE_PROBING
            self.events.append({"t": now, "kind": "probe_start",
                                "executor": exec_id, "seq": self._seq})
        error, after_s = None, 0.0
        if sel.fallback is not None:
            # oversize launch: the partitioned SPMD program when the lane
            # has a mesh behind it, else the padded per-graph oracle. A
            # PartitionInfeasible reroutes to the oracle on the *same*
            # launch, so the request resolves to exactly one of
            # served_partitioned / served_fallback — never both.
            kind, reqs = "fallback", [sel.fallback]
            self._remove_pending(sel.fallback)
            try:
                if getattr(executor, "can_partition", False):
                    try:
                        out, svc = executor.run_partitioned(
                            sel.fallback.graph)
                        kind = "partitioned"
                    except PartitionInfeasible:
                        if not getattr(executor, "can_fallback", False):
                            raise
                        out, svc = executor.run_fallback(sel.fallback.graph)
                else:
                    out, svc = executor.run_fallback(sel.fallback.graph)
            except Exception as e:     # noqa: BLE001 — lane fault, not ours
                out, svc = None, 0.0
                error, after_s = FAIL_CRASH, getattr(e, "after_s", 0.0)
        else:
            kind, reqs = "packed", sel.requests
            for r in reqs:
                self._remove_pending(r)
            batch, k = P.pack_graphs([r.graph for r in reqs],
                                     self.cfg.node_budget,
                                     self.cfg.edge_budget,
                                     self.cfg.max_graphs)
            assert k == len(reqs), "selection must fit the budgets"
            try:
                out, svc = executor.run_batch(batch)
            except Exception as e:     # noqa: BLE001 — lane fault, not ours
                out, svc = None, 0.0
                error, after_s = FAIL_CRASH, getattr(e, "after_s", 0.0)
            if error is None:
                self._fills.append(len(reqs))
                self._nodes_used += sum(r.graph.num_nodes for r in reqs)
        done = now + (after_s if error else svc)
        if not math.isfinite(done) \
                and not math.isfinite(self.cfg.launch_timeout_s):
            raise RuntimeError(
                f"launch {self._seq} on lane {exec_id} would hang forever: "
                f"service time is {svc} and no launch_timeout_s is "
                "configured — set SchedulerConfig.launch_timeout_s")
        unit = _Inflight(kind, reqs, out, now, done, self._seq,
                         error=error, probe=probe)
        self.launches.append({"seq": self._seq, "kind": kind,
                              "executor": exec_id, "probe": probe,
                              "status": None,
                              "req_ids": [r.req_id for r in reqs]})
        self.inflight[exec_id] = unit
        self._seq += 1

    def _complete_due(self, now: float):
        while True:
            due = [(self._due_s(u), ex) for ex, u in self.inflight.items()
                   if self._due_s(u) <= now]
            if not due:
                return
            t, ex = min(due)
            u = self.inflight.pop(ex)
            error = u.error
            if error is None and u.done_s > \
                    u.launch_s + self.cfg.launch_timeout_s:
                error = FAIL_TIMEOUT
            if error is None and self._nonfinite_outputs(u):
                error = FAIL_NONFINITE
            if error is not None:
                self._fail_launch(ex, u, error, t)
                continue
            self.launches[u.seq]["status"] = "ok"
            status = {"packed": SERVED_PACKED,
                      "partitioned": SERVED_PARTITIONED}.get(
                          u.kind, SERVED_FALLBACK)
            for k, r in enumerate(u.requests):
                out = None
                if u.outputs is not None:
                    arr = np.asarray(u.outputs)
                    out = arr[k] if u.kind == "packed" else arr
                self.responses.append(Response(
                    r.req_id, r.tenant, status, r.arrival_s, u.launch_s,
                    u.done_s, out, u.seq, ex))
            self._lane_success(ex, u)
            if self.lanes[ex].state != LANE_QUARANTINED:
                # a quarantined lane's straggling completion must not
                # repopulate the detector state forget() just cleared
                self.detector.record(f"exec{ex}", u.done_s - u.launch_s)
            self._apply_health_actions(u.done_s)

    # --------------------------------------------------- failure handling --
    def _nonfinite_outputs(self, u: _Inflight) -> bool:
        """Output guard: a launch whose result rows contain NaN/Inf is a
        failed launch (corrupted lane), not an answer to serve."""
        if u.outputs is None:
            return False
        try:
            arr = np.asarray(u.outputs)
        except Exception:              # noqa: BLE001 — unscreenable object
            return False
        if not np.issubdtype(arr.dtype, np.floating):
            return False
        rows = arr[:len(u.requests)] if u.kind == "packed" else arr
        return not bool(np.isfinite(rows).all())

    def _fail_launch(self, ex: int, u: _Inflight, error: str, fail_s: float):
        """A launch failed (crash / timeout / non-finite outputs): mark
        it, punish the lane, and re-pack every rider exactly once — or
        dead-letter it as ``failed`` after ``max_retries``."""
        self.launches[u.seq]["status"] = error
        self.failed_launches += 1
        self.events.append({"t": fail_s, "kind": "launch_failed",
                            "executor": ex, "seq": u.seq, "error": error,
                            "req_ids": [r.req_id for r in u.requests]})
        self._note_failure(ex, fail_s, error)
        for r in u.requests:
            r.attempts += 1
            if r.attempts > self.cfg.max_retries:
                self.responses.append(Response(
                    r.req_id, r.tenant, FAILED, r.arrival_s, u.launch_s,
                    fail_s, None, u.seq, ex))
            else:
                backoff = min(
                    self.cfg.retry_backoff_s * (2 ** (r.attempts - 1)),
                    self.cfg.retry_backoff_cap_s)
                r.not_before_s = fail_s + backoff
                self._requeue(r)
                self.retries += 1

    def _note_failure(self, ex: int, t: float, error: str):
        lane = self.lanes[ex]
        lane.failures += 1
        lane.consecutive_failures += 1
        if lane.state == LANE_PROBING:
            self.probes_failed += 1
            self._quarantine(ex, t, f"probe_failed:{error}")
        elif lane.state == LANE_QUARANTINED:
            # evicted-while-busy lane whose straggling launch then
            # failed: extend the quarantine
            self._quarantine(ex, t, error)
        elif lane.consecutive_failures >= self.cfg.quarantine_after:
            self._quarantine(ex, t, error)
        else:
            lane.state = LANE_DEGRADED

    def _lane_success(self, ex: int, u: _Inflight):
        lane = self.lanes[ex]
        lane.consecutive_failures = 0
        if lane.state == LANE_PROBING:
            self.probes_succeeded += 1
            lane.state = LANE_HEALTHY
            self.events.append({"t": u.done_s, "kind": "probe_success",
                                "executor": ex, "seq": u.seq})
            self._replan_pool(u.done_s)
        elif lane.state == LANE_DEGRADED:
            lane.state = LANE_HEALTHY

    def _quarantine(self, ex: int, t: float, reason: str):
        """Take a lane out of the pool for a capped-exponential cooldown;
        it returns through a single canary probe launch. Clears its
        straggler-detector state so stale EMAs cannot re-flag it."""
        lane = self.lanes[ex]
        cooldown = min(
            self.cfg.quarantine_cooldown_s * (2 ** lane.quarantines),
            self.cfg.quarantine_cooldown_cap_s)
        lane.state = LANE_QUARANTINED
        lane.probe_at_s = t + cooldown
        lane.quarantines += 1
        self.detector.forget(f"exec{ex}")
        self.events.append({"t": t, "kind": "quarantine", "executor": ex,
                            "reason": reason,
                            "probe_at_s": lane.probe_at_s})
        self._replan_pool(t)

    def _apply_health_actions(self, t: float):
        """Straggler policy: a lane flagged ``evict`` by the detector is
        quarantined — no new launches land on it until its probe, so its
        would-have-been work re-packs onto the healthy lanes. The last
        available lane is never quarantined for mere slowness (hard
        failures may still quarantine it; the probe-back bounds the
        outage)."""
        for host, action in self.detector.check().items():
            if action != "evict" or not host.startswith("exec"):
                continue
            i = int(host[len("exec"):])
            if self.lanes[i].state == LANE_QUARANTINED:
                continue
            if len(self._available()) > 1:
                self._quarantine(i, t, "straggler")

    def _replan_pool(self, t: float):
        """Re-plan the executor pool through ``runtime.elastic`` whenever
        lane availability changes (quarantine / probe-back), so pool
        shrinkage rides the same planning rule as elastic recovery."""
        n = len(self._available())
        plan = pool_plan(n, self.cfg.shards_per_executor) if n else \
            {"n_lanes": 0, "mesh_shape": (), "axes": ()}
        self.pool_events.append({"t": float(t), **plan})


# ------------------------------------------------------------- simulation --

def poisson_trace(n: int, load_graphs_per_s: float,
                  ds_cfg: P.GraphDataConfig, seed: int = 0,
                  tenants=(("default", 1.0),)) -> list:
    """Open-loop Poisson arrival trace: ``n`` (time, graph, tenant)
    tuples with exponential inter-arrivals at the offered load, graphs
    drawn deterministically from ``ds_cfg``, tenants sampled from the
    (name, weight) mixture. Same (seed, cfg) -> same trace, always."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5E44]))
    names = [t for t, _ in tenants]
    w = np.array([p for _, p in tenants], float)
    w = w / w.sum()
    t = 0.0
    trace = []
    for i in range(n):
        t += float(rng.exponential(1.0 / load_graphs_per_s))
        tenant = names[int(rng.choice(len(names), p=w))]
        trace.append((t, P.make_graph(ds_cfg, i), tenant))
    return trace


def run_trace(sched: ContinuousScheduler, trace) -> list:
    """Drive an arrival trace (iterable of (time, graph, tenant)) through
    the scheduler to completion; returns the response list. The trace is
    sorted into arrival order first, so unsorted traces replay the same
    schedule as their sorted equivalent; an arrival before the
    scheduler's current clock (or a non-finite arrival time) raises an
    actionable error naming the offending entry instead of the opaque
    "clock cannot run backwards" crash. Purely event-driven: the clock
    jumps between arrivals, deadline expiries, and completions — never
    sleeps."""
    trace = list(trace)
    t0 = sched.clock.now()
    for i, (t, _g, _tn) in enumerate(trace):
        if not math.isfinite(t):
            raise ValueError(
                f"trace entry #{i} has non-finite arrival time {t!r}")
        if t < t0 - 1e-12:
            raise ValueError(
                f"trace entry #{i} arrives at t={t}s, before the "
                f"scheduler clock (t={t0}s): run_trace sorts arrivals "
                "into time order but cannot rewind the clock — start the "
                "VirtualClock at or before the earliest arrival")
    ordered = sorted(enumerate(trace), key=lambda p: (p[1][0], p[0]))
    for _, (t, graph, tenant) in ordered:
        while True:
            e = sched.next_event_s()
            if e is None or e > t:
                break
            sched.clock.advance_to(e)
            sched.tick()
        sched.clock.advance_to(t)
        sched.submit(graph, tenant)
    sched.drain()
    return sched.responses


def simulate_wave_drain(trace, cfg: SchedulerConfig, executor):
    """Virtual-time oracle of ``launch.serve.drain_gnn_queue`` under an
    arrival process: wait until ``cfg.max_graphs`` requests have arrived
    (the wave window), pack the window, run its batches back-to-back,
    repeat; the final partial window flushes at end of trace. Uses the
    same Response accounting and ``summarize`` as the continuous
    scheduler, so the two are directly comparable. Returns
    (responses, summary)."""
    responses: list = []
    fills: list = []
    nodes_used = 0
    busy = 0.0
    seq = 0

    def run_window(reqs, now):
        nonlocal busy, seq, nodes_used
        fit = [r for r in reqs if P.graph_fits_budget(
            r.graph, cfg.node_budget, cfg.edge_budget)]
        over = [r for r in reqs if r not in fit]
        batches, dropped = P.pack_dataset(
            [r.graph for r in fit], cfg.node_budget, cfg.edge_budget,
            cfg.max_graphs)
        assert not dropped
        t = max(now, busy)
        i = 0
        for b in batches:
            k = int(b["num_graphs"])
            out, svc = executor.run_batch(b)
            done = t + svc
            for j, r in enumerate(fit[i:i + k]):
                row = None if out is None else np.asarray(out)[j]
                responses.append(Response(r.req_id, r.tenant, SERVED_PACKED,
                                          r.arrival_s, t, done, row, seq))
            fills.append(k)
            nodes_used += sum(r.graph.num_nodes for r in fit[i:i + k])
            i += k
            t = done
            seq += 1
        for r in over:
            status = None
            if getattr(executor, "can_partition", False):
                try:
                    out, svc = executor.run_partitioned(r.graph)
                    status = SERVED_PARTITIONED
                except PartitionInfeasible:
                    status = None
            if status is None and getattr(executor, "can_fallback", False):
                out, svc = executor.run_fallback(r.graph)
                status = SERVED_FALLBACK
            if status is not None:
                done = t + svc
                row = None if out is None else np.asarray(out)
                responses.append(Response(r.req_id, r.tenant, status,
                                          r.arrival_s, t, done, row, seq))
                t = done
                seq += 1
            else:
                responses.append(Response(r.req_id, r.tenant,
                                          REJECTED_OVERSIZE, r.arrival_s))
        busy = t

    window: list = []
    last_t = 0.0
    ordered = sorted(enumerate(trace), key=lambda p: (p[1][0], p[0]))
    for rid, (t, graph, tenant) in ordered:
        window.append(Request(rid, graph, tenant, t))
        last_t = t
        if len(window) >= cfg.max_graphs:
            run_window(window, t)
            window = []
    if window:
        run_window(window, last_t)
    return responses, summarize(responses, fills=fills,
                                max_graphs=cfg.max_graphs,
                                node_budget=cfg.node_budget,
                                nodes_used=nodes_used)
