"""Straggler detection and mitigation policy.

At 1000+ nodes, synchronous SPMD steps run at the pace of the slowest
host. The monitor keeps an EMA of per-host step durations and flags hosts
exceeding ``threshold`` x the fleet median; the mitigation policy is
(1) re-fetch input shards from a backup loader for flagged hosts (data
stalls dominate in practice), then (2) evict-and-replace through the
elastic replan path if a host stays flagged for ``evict_after`` checks.
"""
from __future__ import annotations

import dataclasses
import statistics


@dataclasses.dataclass
class HostStat:
    ema: float = 0.0
    n: int = 0
    flagged_streak: int = 0


class StragglerDetector:
    def __init__(self, decay: float = 0.9, threshold: float = 1.5,
                 evict_after: int = 3):
        self.decay = decay
        self.threshold = threshold
        self.evict_after = evict_after
        self.hosts: dict = {}

    def record(self, host: str, step_seconds: float):
        st = self.hosts.setdefault(host, HostStat())
        if st.n == 0:
            st.ema = step_seconds
        else:
            st.ema = self.decay * st.ema + (1 - self.decay) * step_seconds
        st.n += 1

    def forget(self, host: str):
        """Drop a host's accumulated state. Call when a lane is retired
        or quarantined: a lane out of the pool must stop contributing to
        the fleet median and must not be re-flagged by ``check()`` on
        stale EMAs — and when it probes back in, its record restarts
        from the first fresh sample (tests/test_runtime.py pins this)."""
        self.hosts.pop(host, None)

    def median_ema(self) -> float:
        vals = [s.ema for s in self.hosts.values() if s.n > 0]
        return statistics.median(vals) if vals else 0.0

    def check(self) -> dict:
        """Returns {host: action} where action is 'reshard_input' or
        'evict'. Updates flag streaks."""
        med = self.median_ema()
        actions = {}
        if med <= 0:
            return actions
        for host, st in self.hosts.items():
            if st.ema > self.threshold * med:
                st.flagged_streak += 1
                actions[host] = ("evict" if st.flagged_streak
                                 >= self.evict_after else "reshard_input")
            else:
                st.flagged_streak = 0
        return actions
