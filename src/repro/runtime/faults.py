"""Deterministic fault injection for the serving runtime.

The fault-tolerance claims in docs/SERVING.md (§Fault tolerance) are
only testable if every failure mode replays bit-identically: a flaky
"kill the process at a random moment" harness pins nothing. This module
injects faults *on the virtual timeline* instead — a ``FaultPlan`` is a
script (or a seed) naming which executor calls fail and how, and
``FaultyExecutor`` wraps any executor implementing the
``run_batch``/``run_fallback`` protocol (``SimExecutor``,
``MeasuredExecutor``, a sharded lane) and applies the plan call by
call. jax-free, clock-injected, zero sleeps — the same plan against the
same trace produces the same schedule, the same retries, the same
quarantines, every run.

Fault kinds (matching the scheduler's failure taxonomy):

``crash``
    The call raises ``ExecutorCrash`` surfacing ``after_s`` seconds of
    virtual time after launch (0.0 = at launch). The scheduler fails
    the launch, punishes the lane, and re-packs the riders.
``hang``
    The call "never" completes: service time becomes ``inf``. Only the
    scheduler's ``launch_timeout_s`` can reclaim the lane — this is the
    failure mode the timeout exists for.
``slowdown``
    Transient degradation: service time multiplied by ``factor``. Not a
    hard failure — it exercises the straggler-detector path.
``corrupt``
    The call completes on time but its outputs are poisoned with
    ``value`` (NaN by default, use ``inf`` for the other half of the
    screen). Caught by the scheduler's non-finite output guard.

Usage::

    plan = FaultPlan([FaultSpec("crash", launch=3),
                      FaultSpec("hang", launch=7)])
    lane = FaultyExecutor(SimExecutor(constant_service(0.01)), plan)

    # or seed-driven, for the chaos benchmark:
    plan = FaultPlan.random(seed=0, n_calls=500,
                            rates={"crash": 0.03, "hang": 0.02,
                                   "corrupt": 0.03, "slowdown": 0.04})
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.runtime.scheduler import ExecutorCrash

KINDS = ("crash", "hang", "slowdown", "corrupt")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault. Target the wrapped executor's ``launch``-th
    call (0-based, counting batch and fallback calls together) or the
    first call at/after virtual time ``at_s`` (needs a clock); exactly
    one of the two must be set. Each spec fires at most once."""
    kind: str
    launch: int | None = None
    at_s: float | None = None
    factor: float = 4.0          # slowdown multiplier
    after_s: float = 0.0         # crash: virtual delay before surfacing
    value: float = float("nan")  # corrupt: poison value

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if (self.launch is None) == (self.at_s is None):
            raise ValueError(
                "FaultSpec needs exactly one trigger: launch= or at_s=")


class FaultPlan:
    """An ordered script of ``FaultSpec``s. Shareable across lanes only
    if you want correlated failures — normally build one plan per
    wrapped executor. ``injected`` on the wrapping ``FaultyExecutor``
    logs what actually fired."""

    def __init__(self, specs=()):
        self.specs = list(specs)
        self._fired = [False] * len(self.specs)

    def take(self, call_index: int, now: float | None) -> FaultSpec | None:
        """Consume and return the first unfired spec matching this call
        (by index, or by virtual time when a clock is available)."""
        for i, s in enumerate(self.specs):
            if self._fired[i]:
                continue
            hit = (s.launch == call_index if s.launch is not None
                   else now is not None and now >= s.at_s - 1e-12)
            if hit:
                self._fired[i] = True
                return s
        return None

    @classmethod
    def random(cls, seed: int, n_calls: int, rates: dict,
               factor: float = 4.0) -> "FaultPlan":
        """Seed-driven plan: an independent Bernoulli draw per (call,
        kind) at the given per-call ``rates`` (kind -> probability); at
        most one fault per call, first kind in ``KINDS`` order wins.
        Same (seed, n_calls, rates) -> same plan, always."""
        for k in rates:
            if k not in KINDS:
                raise ValueError(f"unknown fault kind {k!r} in rates")
        rng = np.random.default_rng(np.random.SeedSequence([seed, 0xFA17]))
        specs = []
        for call in range(n_calls):
            draws = rng.random(len(KINDS))  # fixed shape: stable stream
            for k, u in zip(KINDS, draws):
                if u < rates.get(k, 0.0):
                    specs.append(FaultSpec(k, launch=call, factor=factor))
                    break
        return cls(specs)


class FaultyExecutor:
    """Executor-protocol wrapper that applies a ``FaultPlan``. Pass the
    scheduler's clock to enable ``at_s`` triggers; call-index triggers
    need none. ``calls`` counts launches routed through this lane;
    ``injected`` records (call_index, kind) for every fault fired."""

    def __init__(self, inner, plan: FaultPlan, clock=None):
        self.inner = inner
        self.plan = plan
        self.clock = clock
        self.calls = 0
        self.injected: list = []

    @property
    def can_fallback(self) -> bool:
        return getattr(self.inner, "can_fallback", False)

    @property
    def can_partition(self) -> bool:
        return getattr(self.inner, "can_partition", False)

    def run_batch(self, batch: dict):
        return self._run(lambda: self.inner.run_batch(batch),
                         n_rows=int(batch["num_graphs"]))

    def run_fallback(self, graph):
        return self._run(lambda: self.inner.run_fallback(graph), n_rows=1)

    def run_partitioned(self, graph):
        return self._run(lambda: self.inner.run_partitioned(graph),
                         n_rows=1)

    def _run(self, call, n_rows: int):
        idx = self.calls
        self.calls += 1
        now = self.clock.now() if self.clock is not None else None
        spec = self.plan.take(idx, now)
        if spec is None:
            return call()
        self.injected.append((idx, spec.kind))
        if spec.kind == "crash":
            raise ExecutorCrash(f"injected crash at call {idx}",
                                after_s=spec.after_s)
        out, svc = call()
        if spec.kind == "hang":
            return out, math.inf
        if spec.kind == "slowdown":
            return out, svc * spec.factor
        # corrupt: poison the result the guard must catch; fabricate a
        # poisoned row block when the inner executor returns no outputs
        # (pure latency simulation) so the guard still has something to
        # screen
        if out is None:
            poisoned = np.full((n_rows, 1), spec.value, dtype=np.float32)
        else:
            poisoned = np.asarray(out).astype(np.float32).copy()
            poisoned[...] = spec.value
        return poisoned, svc
