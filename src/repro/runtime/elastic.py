"""Elastic scaling: replan the mesh around failed hosts and reshard from
checkpoint.

The checkpoint format is topology-free (see checkpoint.manager), so
recovery is: pick the largest (data', model) grid buildable from the
surviving devices — keeping the model axis if the survivor count allows,
else degrading model parallelism to a divisor — rebuild shardings from
the same logical rules, and device_put the restored arrays.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import Mesh


def plan_mesh_shape(n_devices: int, model_pref: int = 16,
                    pod: int | None = None) -> tuple:
    """Largest (data, model) grid with model | model_pref, data maximal."""
    if n_devices < 1:
        raise ValueError(f"need at least one device, got {n_devices}")
    model = model_pref
    while model > 1 and n_devices % model:
        model //= 2
    data = n_devices // model
    if pod and pod > 1 and data % pod == 0:
        return (pod, data // pod, model), ("pod", "data", "model")
    return (data, model), ("data", "model")


def pool_plan(n_lanes: int, shards_per_executor: int = 1) -> dict:
    """Plan the serving executor pool for ``n_lanes`` healthy lanes, each
    driving ``shards_per_executor`` devices. The scheduler calls this on
    every lane-availability change (quarantine / probe-back), so pool
    shrinkage rides the same (data, model) planning rule as elastic
    training recovery — no second sizing policy."""
    shape, axes = plan_mesh_shape(n_lanes * shards_per_executor,
                                  model_pref=shards_per_executor)
    return {"n_lanes": int(n_lanes), "mesh_shape": tuple(shape),
            "axes": tuple(axes)}


def replan(devices, model_pref: int = 16) -> Mesh:
    shape, axes = plan_mesh_shape(len(devices), model_pref)
    n = int(np.prod(shape))
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def recover(ckpt_manager, template, devices, plan, rules=None,
            model_pref: int = 16):
    """Full recovery path: new mesh from survivors + restore resharded.

    Returns (mesh, restored_tree, meta)."""
    from repro.distributed import sharding as shd
    mesh = replan(devices, model_pref)
    shardings = shd.plan_shardings(plan, mesh, rules)
    tree, meta = ckpt_manager.restore(template, shardings=shardings)
    return mesh, tree, meta
