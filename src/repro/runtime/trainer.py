"""Fault-tolerant training driver.

Wraps a compiled train step with: periodic async checkpointing, automatic
restore-on-restart (resume is exact — the data pipeline is a pure function
of step), straggler monitoring hooks, and a failure-injection point used
by the integration tests to prove the restart path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.straggler import StragglerDetector


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    host: str = "host0"


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn: Callable,
                 batch_fn: Callable, params, opt_state,
                 fail_at_step: int | None = None, log=print):
        self.cfg = cfg
        self.step_fn = step_fn        # (params, opt, batch) -> (p, o, m)
        self.batch_fn = batch_fn      # step -> batch (pure)
        self.params = params
        self.opt_state = opt_state
        self.fail_at_step = fail_at_step
        self.log = log
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.straggler = StragglerDetector()
        self.metrics_history: list = []

    # ------------------------------------------------------------ state --
    def _state(self):
        return {"params": self.params, "opt": self.opt_state}

    def try_resume(self) -> int:
        """Restore latest checkpoint if present; returns start step."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0
        tree, meta = self.ckpt.restore(self._state())
        self.params, self.opt_state = tree["params"], tree["opt"]
        if self.log:
            self.log(f"[trainer] resumed from step {latest}")
        return int(meta["step"])

    # ------------------------------------------------------------- loop --
    def run(self, start_step: int | None = None) -> dict:
        step = self.try_resume() if start_step is None else start_step
        losses = []
        while step < self.cfg.total_steps:
            if self.fail_at_step is not None and step == self.fail_at_step:
                self.fail_at_step = None   # fail once
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.record(self.cfg.host, dt)
            losses.append(float(metrics["loss"]))
            self.metrics_history.append(
                {k: float(v) for k, v in metrics.items()})
            step += 1
            if step % self.cfg.ckpt_every == 0 \
                    or step == self.cfg.total_steps:
                self.ckpt.save_async(step, self._state())
            if self.log and step % self.cfg.log_every == 0:
                self.log(f"[trainer] step {step} "
                         f"loss {metrics['loss']:.4f} ({dt * 1e3:.0f} ms)")
        self.ckpt.wait()
        return {"final_step": step, "losses": losses}
