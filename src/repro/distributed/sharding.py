"""Logical-axis -> mesh-axis sharding rules (GSPMD partition specs).

The rules table is the TPU analogue of GNNBuilder's parallelism factors:
swapping a rule re-parallelizes the generated program without touching the
model definition. ``spec_for`` drops mesh axes that do not divide a dim
(e.g. 8 KV heads on a 16-way model axis) instead of failing — the fallback
is replication, exactly like setting a parallelism factor to 1.

Two consumers share this module:

* the LM scaffold — the logical axes in the rules tables below (batch,
  heads, embed, ...) over 2-D/3-D training and serving meshes;
* the packed GNN path — stacked GraphBatch shard waves over a 1-D
  ``("data",)`` mesh (``launch.mesh.make_data_mesh``): the leading shard
  dim takes ``graph_batch_sharding`` while params stay ``replicated``,
  and ``gnn_model.apply_packed_sharded`` runs one SPMD program with each
  device consuming its own shard. No rules table is needed — a
  GraphBatch is opaque to GSPMD; the partition is decided at pack time
  by ``data.pipeline.shard_pack``.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn import param as P_

# Default logical->mesh rules. Values may be a mesh axis name, a tuple of
# mesh axis names (sharded over their product), or None (replicated).
# "fsdp+tp": weight `embed` dims shard over `data` (GSPMD inserts the
# per-layer all-gather = FSDP). Activations constrain `batch` first, so
# their embed dim stays replicated (the `used` set drops the double-use).
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_flat": ("model",),        # flattened kv projection out-dim
    "mlp": ("model",),
    "vocab": ("model",),
    "embed": ("data",),           # FSDP axis for weights
    "experts": ("model",),        # EP
    "moe_f": ("data",),           # expert ffn inner dim (2D expert shard)
    "kv_seq": ("model",),         # decode KV caches shard sequence on model
    "long_seq": ("data", "model"),  # 500k-context: shard seq over everything
    "seq": (),
    # residual-stream sequence sharding (Megatron-SP): activations between
    # blocks shard their seq dim on `model`; GSPMD inserts the all-gather
    # before attention/mlp and the reduce-scatter after. Keeps scan-saved
    # residuals (the remat working set) 16x smaller.
    "act_seq": ("model",),
    # seq sharding *inside* mixers/ffns: () = gather the sequence at the
    # block boundary (SP+TP); ("model",) = keep tokens sharded through the
    # matmuls and gather weights instead (context-parallel FSDP — the
    # fsdp_seq preset).
    "mixer_seq": (),
    "layers": (),
    "state": ("model",),          # ssm/rwkv inner state channels
    "conv": (),
    "q_lora": (),
    "kv_lora": (),
}

# Pure tensor-parallel preset (weights replicated over `data`) — a DSE /
# hillclimb alternative for small models and latency-critical decode.
TP_ONLY_RULES: dict = {**DEFAULT_RULES, "embed": (), "moe_f": ()}

# Pure FSDP preset: batch shards over EVERY mesh axis (1 seq/device at
# train_4k), weights shard over `data` and are gathered per layer. No
# activation collectives at all — for dense training at >=4k tokens/device
# the weight-gather traffic (~params bytes x3) is ~15x cheaper than the
# SP/TP activation traffic. Napkin math and measurements: EXPERIMENTS §Perf.
FSDP_RULES: dict = {
    **DEFAULT_RULES,
    "batch": ("pod", "data", "model"),
    # weights shard 2D over (data, model): gathers stream over both axes
    # and gradients reduce-scatter instead of all-reducing over a
    # replicated model axis.
    "embed": ("data", "model"),
    "act_seq": (), "heads": (), "kv_flat": (), "mlp": (), "state": (),
    "moe_f": ("model",),   # MoE under fsdp: experts x inner-dim 2D
}

# Context-parallel FSDP: tokens stay sequence-sharded through every
# matmul (zero activation collectives); weights are 2D-sharded and
# gathered per layer; KV replicates per layer for attention (128 MB vs
# the 0.5-1 GB activation gathers it replaces). Best for long-sequence
# prefill of attention archs; NOT for ssm/hybrid (sequential mixers).
FSDP_SEQ_RULES: dict = {
    **DEFAULT_RULES,
    # weights shard over `data` only: a model-axis weight shard would make
    # GSPMD gather the (much larger) seq-sharded activations at each
    # matmul instead of the weights (measured: +19 GB/step on qwen3).
    "embed": ("data",),
    "mixer_seq": ("model",),
    "heads": (), "kv_flat": (), "mlp": (), "vocab": ("model",),
}

# fsdp_tp without sequence-parallel residuals: no boundary gathers at all
# (TP psums remain). Only viable when scan-carry memory is small — i.e.
# few scan iterations x high grad_accum (jamba: 9 superblocks, accum 8).
FSDP_TP_NOSP_RULES: dict = {**DEFAULT_RULES, "act_seq": ()}

RULE_PRESETS = {"fsdp_tp": DEFAULT_RULES, "tp_only": TP_ONLY_RULES,
                "fsdp": FSDP_RULES, "fsdp_seq": FSDP_SEQ_RULES,
                "fsdp_tp_nosp": FSDP_TP_NOSP_RULES}


def auto_preset(cfg, kind: str, multi_pod: bool) -> str:
    """Launcher default: best-known preset per (family x step-kind x mesh),
    from the measured §Perf iterations (EXPERIMENTS.md):
      * dense-family single-pod train: batch=256 over all 256 chips ->
        pure FSDP (no activation collectives; ~15x less traffic than SP+TP)
      * hybrid train: TP without SP — 9 superblocks x accum 8 keep scan
        carries small, dropping all boundary gathers (-31% measured)
      * GQA prefill: context-parallel FSDP (fsdp_seq) — tokens stay
        seq-sharded, KV replicates cheaply (-60..80% measured); MLA
        prefill stays SP+TP (k-expansion gathers made fsdp_seq +28%)
      * MoE train / decode / multi-pod train: SP+TP (EP needs `model`;
        decode parallelism comes from the seq-sharded cache)."""
    family = cfg.family
    has_mla = getattr(cfg, "mla", None) is not None
    if kind == "train":
        if not multi_pod and family in ("dense", "ssm", "audio", "vlm"):
            return "fsdp"
        if family == "hybrid":
            return "fsdp_tp_nosp"
        return "fsdp_tp"
    if kind == "prefill" and not has_mla and family in (
            "dense", "vlm", "moe"):
        return "fsdp_seq"
    return "fsdp_tp"


def _mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_axis(logical: str | None, rules: Mapping, mesh: Mesh,
                 dim_size: int) -> tuple:
    """Mesh axes for one dim, keeping only axes that exist and divide."""
    if logical is None:
        return ()
    entry = rules.get(logical, ())
    if entry is None:
        return ()
    if isinstance(entry, str):
        entry = (entry,)
    sizes = _mesh_axis_sizes(mesh)
    chosen: list = []
    prod = 1
    for ax in entry:
        if ax not in sizes:
            continue
        if dim_size % (prod * sizes[ax]) == 0:
            chosen.append(ax)
            prod *= sizes[ax]
        # else: drop this axis (replicate along it) — divisibility fallback
    return tuple(chosen)


def spec_for(axes: Sequence, shape: Sequence[int], mesh: Mesh,
             rules: Mapping | None = None) -> P:
    rules = rules or DEFAULT_RULES
    parts = []
    used: set = set()
    for logical, dim in zip(axes, shape):
        chosen = tuple(a for a in resolve_axis(logical, rules, mesh, dim)
                       if a not in used)
        used.update(chosen)
        if len(chosen) == 0:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    return P(*parts)


def plan_shardings(plan, mesh: Mesh, rules: Mapping | None = None):
    """NamedSharding tree for a parameter plan."""
    return P_.tree_map_specs(
        lambda s: NamedSharding(mesh, spec_for(s.axes, s.shape, mesh, rules)),
        plan)


def plan_pspecs(plan, mesh: Mesh, rules: Mapping | None = None):
    return P_.tree_map_specs(
        lambda s: spec_for(s.axes, s.shape, mesh, rules), plan)


def constrain(x, mesh: Mesh, axes: Sequence, rules: Mapping | None = None):
    """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
    spec = spec_for(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement — the params of the sharded GNN path
    (every device holds the whole model; only the graphs are split)."""
    return NamedSharding(mesh, P())


def graph_batch_sharding(mesh: Mesh) -> NamedSharding:
    """Leading-dim ``data`` placement for stacked GraphBatch shard
    waves: array leaves are (num_shards, ...), one shard per device of
    the 1-D ("data",) mesh; trailing dims replicate. The PartitionSpec
    is rank-agnostic, so the same sharding serves every leaf of the
    stacked batch dict (node tables, edge streams, scalars-per-shard)."""
    return NamedSharding(mesh, P("data"))


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_devices(mesh: Mesh) -> int:
    return int(np.prod(mesh.devices.shape))
