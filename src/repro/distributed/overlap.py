"""Compute/communication overlap: collective matmul (shard_map).

XLA's latency-hiding scheduler overlaps async collectives with compute on
TPU, but the *algorithmic* overlap for TP boundaries is the collective
matmul: instead of all-gather(X) then X@W, rotate shards around the ring
with ppermute and accumulate one shard-slice of the product per step —
each permute overlaps with the previous step's matmul. This removes the
serialized all-gather from the critical path (Wang et al., "Overlap
communication with dependent computation", the pattern behind Megatron's
`--overlap-grad-reduce`-style schedules on TPU).

``ag_matmul``  : Y = all_gather(X, seq) @ W        (forward TP boundary)
``matmul_rs``  : Y = reduce_scatter(X @ W, seq)    (output TP boundary)
Used opt-in via shard_map on the `model` axis; the pjit path keeps plain
GSPMD collectives (the dry-run measures those), and equivalence is tested
against the unoverlapped reference on a fake multi-device mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _ring_perm(n: int):
    return [(j, (j + 1) % n) for j in range(n)]


def ag_matmul_local(x_local, w, axis_name: str):
    """Per-shard body: y = all_gather(x, axis) @ w, overlapped.

    x_local: (m_local, k) — this shard's rows of the seq/row-sharded X.
    w: (k, n) replicated. Returns (m_local * world, n): the full product,
    computed as `world` local matmuls, each overlapping the ring permute
    that fetches the next shard.
    """
    world = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)
    m_local, _ = x_local.shape
    n_out = w.shape[1]
    out = jnp.zeros((m_local * world, n_out), x_local.dtype)

    def body(i, carry):
        out, x_cur = carry
        y = x_cur @ w                                # compute ...
        x_next = jax.lax.ppermute(                   # ... overlaps permute
            x_cur, axis_name, _ring_perm(world))
        src = (me - i) % world                       # whose rows these are
        out = jax.lax.dynamic_update_slice(out, y, (src * m_local, 0))
        return out, x_next

    out, _ = jax.lax.fori_loop(0, world, body, (out, x_local))
    return out


def matmul_rs_local(x_local, w_local, axis_name: str):
    """Per-shard body: y = reduce_scatter(x @ w, rows), overlapped.

    x_local: (m, k_local) row-full, contraction-sharded; w_local:
    (k_local, n). Returns (m / world, n): this shard's rows of the reduced
    product. Each step computes the slice destined for one shard and
    ring-forwards the partial accumulator (matmul overlaps the permute).
    """
    world = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)
    m = x_local.shape[0]
    assert m % world == 0
    m_loc = m // world
    n_out = w_local.shape[1]

    def slice_for(dst):
        return jax.lax.dynamic_slice(x_local, (dst * m_loc, 0),
                                     (m_loc, x_local.shape[1]))

    def contrib(dst):
        return (slice_for(dst) @ w_local).astype(jnp.float32)

    # ring schedule: at step i, shard `me` adds its contribution for
    # destination (me - i - 1) and forwards; the accumulator for shard d
    # visits every shard and arrives home at the final (unpermuted) step.
    def body(i, acc):
        d = (me - i - 1) % world
        acc = acc + contrib(d)
        return jax.lax.ppermute(acc, axis_name, _ring_perm(world))

    acc = jax.lax.fori_loop(0, world - 1, body,
                            jnp.zeros((m_loc, n_out), jnp.float32))
    acc = acc + contrib(me)          # d_{w-1}(me) == me
    return acc.astype(x_local.dtype)


def make_overlapped_ops(mesh: Mesh, axis: str = "model"):
    """shard_map-wrapped (ag_matmul, matmul_rs) bound to a mesh axis."""
    other = tuple(a for a in mesh.axis_names if a != axis)

    ag = shard_map(
        functools.partial(ag_matmul_local, axis_name=axis), mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None), check_rep=False)

    rs = shard_map(
        functools.partial(matmul_rs_local, axis_name=axis), mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None), check_rep=False)
    return ag, rs
