"""HLO-text analysis: collective byte accounting for the roofline.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled (post-SPMD, per-device) HLO module and sum the operand bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Shapes in the per-device program are shard-local, so
the totals are bytes-through-ICI *per chip*. Collectives inside scan
(`while`) bodies are multiplied by the loop trip count, with nesting
handled by propagating scales along the while-call graph.
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"((?:all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?)\(")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")


def shape_bytes(text: str) -> int:
    """Sum bytes over every dtype[dims] shape token in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo_text: str) -> dict:
    """computation name -> list of body lines."""
    comps: dict = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s:
            toks = s.split()
            first = toks[1] if toks[0] == "ENTRY" else toks[0]
            cur = first.strip("%")
            comps[cur] = []
        elif s == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _while_edges(comps: dict) -> list:
    """(enclosing_comp, body_comp, trip_count) for each while op."""
    edges = []
    for name, lines in comps.items():
        for line in lines:
            if " while(" not in line:
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
            if mb:
                edges.append((name, mb.group(1),
                              int(mt.group(1)) if mt else 1))
    return edges


def _comp_scales(comps: dict) -> dict:
    """Effective execution multiplier per computation (nested whiles)."""
    scales = defaultdict(lambda: 1)
    edges = _while_edges(comps)
    # propagate: body scale = trip * enclosing scale; iterate to fixpoint
    for _ in range(8):
        changed = False
        for parent, body, trip in edges:
            s = scales[parent] * trip
            if scales[body] != s:
                scales[body] = s
                changed = True
        if not changed:
            break
    return scales


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:                      # iota format [num_groups, group_size]<=[N]
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x])
    return 2


def _line_collective(line: str):
    """Per-chip ICI bytes for one collective, from its RESULT shape
    (post-opt HLO prints operands bare). Ring-algorithm accounting:
      all-gather   : chip receives ~result bytes        -> R
      all-reduce   : reduce-scatter + all-gather        -> 2R
      reduce-scatter: sends (g-1)/g of the g*R operand  -> R*(g-1)
      all-to-all   : exchanges ~its shard               -> R
      collective-permute: one shard hop                 -> R

    Returns (op, raw_bytes, tpu_bytes). ``tpu_bytes`` corrects two
    XLA:CPU-pipeline artifacts that the TPU pipeline does not have
    (verified on a minimal FSDP matmul, see EXPERIMENTS.md §Dry-run):
      * CPU float-support upcasts bf16 dots to f32, so weight/grad
        collectives appear at 2x width -> halve f32 collective bytes
        (model wire dtype is bf16 by design; genuinely-f32 traffic such
        as scalar losses is negligible).
      * CPU lacks the all-reduce->reduce-scatter rewrite for gradient
        syncs whose consumers are sharded -> count gradient ARs
        (op_name contains "transpose(jvp") at RS volume (1R not 2R).
    """
    m = _COLL_RE.search(line)
    if not m:
        return None
    op = m.group(2).replace("-start", "").replace("-done", "")
    if "-done" in m.group(2):
        return None            # counted at -start
    r = shape_bytes(m.group(1))
    g = _group_size(line)
    if op == "all-reduce":
        b = 2 * r
    elif op == "reduce-scatter":
        b = r * max(g - 1, 1)
    else:
        b = r
    tpu = b
    is_grad = "transpose(jvp" in line
    if op == "all-reduce" and is_grad:
        tpu = r                          # RS volume
    if re.search(r"=\s*\(?f32\[", line) or " (f32[" in line:
        tpu //= 2                        # bf16 on the wire on TPU
    return op, b, tpu


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+\[[0-9,]*\])")
_DOT_RE = re.compile(
    r"%([\w\.\-]+)\s*=\s*([a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?\s+dot\("
    r"%([\w\.\-]+),\s*%([\w\.\-]+)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dims(shape_tok: str) -> list:
    m = _SHAPE_RE.search(shape_tok)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def dot_stats(hlo_text: str) -> dict:
    """True per-chip HLO matmul FLOPs/bytes, scaled by while trip counts.

    ``cost_analysis()`` counts each scan body once; here each ``dot`` op
    contributes 2 * prod(result_dims) * prod(contracting_dims) FLOPs
    (contracting sizes resolved via the operand-name -> shape map) times
    its computation's execution multiplier. ``bytes`` sums dot operand +
    result bytes (a matmul-traffic estimate of HBM bytes; elementwise is
    excluded and noted in the roofline).
    """
    comps = _split_computations(hlo_text)
    scales = _comp_scales(comps)
    shapes: dict = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    flops = 0
    bytes_ = 0
    n_dots = 0
    for name, lines in comps.items():
        scale = scales.get(name, 1)
        for line in lines:
            m = _DOT_RE.search(line)
            if not m:
                continue
            res, lhs, rhs = m.group(2), m.group(3), m.group(4)
            res_dims = _dims(res)
            lhs_shape = shapes.get(lhs)
            mc = _LHS_C_RE.search(line)
            contract = 1
            if lhs_shape and mc:
                ld = _dims(lhs_shape)
                for d in mc.group(1).split(","):
                    if d:
                        contract *= ld[int(d)]
            n = 1
            for d in res_dims:
                n *= d
            flops += 2 * n * contract * scale
            b = shape_bytes(res)
            for opnd in (lhs, rhs):
                if opnd in shapes:
                    b += shape_bytes(shapes[opnd])
            bytes_ += b * scale
            n_dots += scale
    return {"flops": flops, "bytes": bytes_, "count": n_dots}


def collective_stats(hlo_text: str, scale_by_trip_count: bool = True) -> dict:
    """Per-collective {bytes, tpu_bytes, count} totals (per-chip ICI)."""
    comps = _split_computations(hlo_text)
    scales = _comp_scales(comps) if scale_by_trip_count else {}
    stats = {c: {"bytes": 0, "tpu_bytes": 0, "count": 0}
             for c in COLLECTIVES}
    for name, lines in comps.items():
        scale = scales.get(name, 1) if scale_by_trip_count else 1
        for line in lines:
            got = _line_collective(line)
            if got is None:
                continue
            op, b, tpu = got
            stats[op]["bytes"] += b * scale
            stats[op]["tpu_bytes"] += tpu * scale
            stats[op]["count"] += scale
    stats["total_bytes"] = sum(v["bytes"] for v in stats.values()
                               if isinstance(v, dict))
    stats["tpu_total_bytes"] = sum(v["tpu_bytes"] for v in stats.values()
                                   if isinstance(v, dict))
    stats["total_count"] = sum(v["count"] for v in stats.values()
                               if isinstance(v, dict))
    return stats
