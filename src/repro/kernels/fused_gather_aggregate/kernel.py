"""Pallas TPU kernel: fused gather -> phi -> aggregate over packed COO.

GNNBuilder's core dataflow claim (paper SV-A, Fig. 3) is that messages
*stream* through the gather -> phi -> aggregate pipeline instead of being
materialized. The `segment_aggregate` kernel (PR 2) fused only the
aggregate stage: every conv still wrote an (E, F) message tensor to HBM
via `jnp.take` before reducing it. This kernel closes that seam for the
linear-phi family (GCN / SAGE / GIN-without-edge-MLP): it consumes the
node-feature table (N, F) plus the raw `src`/`dst` edge-id streams and an
optional per-edge scale (the GCN 1/sqrt(d_u d_v) norm), gathers source
rows *inside* the edge-block loop, and folds them straight into the VMEM
node accumulator — the (E, F) message tensor never touches HBM.

Grid: (node_tiles, edge_tiles) — the edge axis is innermost/sequential,
so each node tile's accumulator persists in VMEM across the whole edge
stream (same schedule as `segment_aggregate`). Block shapes:
  x     (N, F)   — the full node-feature table, resident across steps
  src   (1, EB)  — source node ids (-1 = padding, gathers a zero row)
  dst   (1, EB)  — destination ids (-1 = padding, matches no node row)
  scale (1, EB)  — per-edge message scale (1.0 when unused, 0 on padding)
  out   (NB, F)  — this node tile's aggregate (revisited across j)
Scratch: count (NB, 1).

The gather itself is routed through the MXU: a (N, EB) source one-hot
(with the edge scale folded in, so phi costs nothing extra) contracted
against the node table yields the edge block's scaled messages without a
serial gather loop; the scatter side reuses the segment kernel's
destination one-hot matmul / fori-loop updates.

The node table is dtype-polymorphic: fp32, bf16, or int8 tables stay
resident in VMEM at their storage width — the PrecisionPolicy bandwidth
lever for the gather stage — and the gather contraction + every
accumulator run in fp32 (int8 values are integer-valued fp32, so the
accumulation is exact int32-style). For int8 tables the per-tensor
dequantization scale is folded into the per-edge ``scale`` stream by the
caller (core.aggregations), so dequantization also costs nothing extra.

Supported: sum, mean, min, max — the family GCN/SAGE/GIN lower to.
var/std (PNA towers) and per-edge MLPs keep the materialized path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

AGGS = ("sum", "mean", "min", "max")


def _fused_kernel(x_ref, src_ref, dst_ref, scale_ref, out_ref, cnt_ref, *,
                  agg: str, edge_steps: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nb, f = out_ref.shape
    eb = src_ref.shape[1]
    n_src = x_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        if agg in ("sum", "mean"):
            out_ref[...] = jnp.zeros_like(out_ref)
        elif agg == "min":
            out_ref[...] = jnp.full(out_ref.shape, jnp.inf, out_ref.dtype)
        else:
            out_ref[...] = jnp.full(out_ref.shape, -jnp.inf, out_ref.dtype)

    # gather prologue: (N, EB) source one-hot with the per-edge scale
    # folded in, contracted against the node table on the MXU. Padding
    # edges (src == -1) match no row and gather an all-zero message.
    node_rows = jax.lax.broadcasted_iota(jnp.int32, (n_src, 1), 0)
    src_onehot = (src_ref[...] == node_rows).astype(jnp.float32) \
        * scale_ref[...].astype(jnp.float32)
    msg = jax.lax.dot_general(
        src_onehot, x_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (EB, F)

    # (NB, EB) edge->node assignment for this tile pair; padding edges
    # carry dst == -1 and match no node row.
    node_ids = i * nb + jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    onehot = dst_ref[...] == node_ids

    if agg in ("sum", "mean"):
        onef = onehot.astype(jnp.float32)
        out_ref[...] += jnp.dot(onef, msg,
                                preferred_element_type=jnp.float32)
        cnt_ref[...] += jnp.sum(onef, axis=1, keepdims=True)
    else:
        def body(e, state):
            acc, cnt = state
            sel = jax.lax.dynamic_slice(onehot, (0, e), (nb, 1))
            row = jax.lax.dynamic_slice(msg, (e, 0), (1, f))
            upd = jnp.minimum(acc, row) if agg == "min" \
                else jnp.maximum(acc, row)
            return (jnp.where(sel, upd, acc),
                    cnt + sel.astype(jnp.float32))
        acc, cnt = jax.lax.fori_loop(
            0, eb, body, (out_ref[...], cnt_ref[...]))
        out_ref[...] = acc
        cnt_ref[...] = cnt

    @pl.when(j == edge_steps - 1)
    def _finalize():
        if agg == "mean":
            out_ref[...] = out_ref[...] / jnp.maximum(cnt_ref[...], 1.0)
        elif agg in ("min", "max"):
            o = out_ref[...]
            out_ref[...] = jnp.where(jnp.isfinite(o), o, 0.0)


def fused_gather_aggregate_pallas(x, src, dst, num_segments: int, *,
                                  scale=None, agg: str = "sum",
                                  edge_block: int = 128,
                                  node_block: int = 128,
                                  interpret: bool = True):
    """x: (N, F) node features in fp32, bf16, or int8 (the table streams
    and stays VMEM-resident at its storage width; accumulation is fp32);
    src/dst: (E,) int32 endpoint id streams of the packed COO edge
    buffer (-1 or any out-of-range id = padding); scale: optional (E,)
    per-edge message scale (phi), applied before aggregation — int8
    callers fold the dequant scale in here. Returns (num_segments, F)
    float32 aggregates; empty segments zero-fill. The (E, F) message
    tensor is never materialized.
    """
    assert agg in AGGS, agg
    n_src, f = x.shape
    e = src.shape[0]
    if e == 0 or num_segments == 0:
        return jnp.zeros((num_segments, f), jnp.float32)
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    # out-of-range ids (packed-batch overflow bucket, -1 padding) are
    # normalized to -1 on *both* streams so a bad edge neither gathers
    # nor scatters
    bad = (src < 0) | (src >= n_src) | (dst < 0) | (dst >= num_segments)
    src = jnp.where(bad, -1, src)
    dst = jnp.where(bad, -1, dst)
    if scale is None:
        scale = jnp.ones((e,), jnp.float32)
    scale = jnp.where(bad, 0.0, scale.astype(jnp.float32))
    eb = min(edge_block, e)
    nb = min(node_block, num_segments)
    e_pad = (-e) % eb
    n_pad = (-num_segments) % nb
    if e_pad:
        src = jnp.pad(src, (0, e_pad), constant_values=-1)
        dst = jnp.pad(dst, (0, e_pad), constant_values=-1)
        scale = jnp.pad(scale, (0, e_pad))
    grid = ((num_segments + n_pad) // nb, (e + e_pad) // eb)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, agg=agg, edge_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src, f), lambda i, j: (0, 0)),
            pl.BlockSpec((1, eb), lambda i, j: (0, j)),
            pl.BlockSpec((1, eb), lambda i, j: (0, j)),
            pl.BlockSpec((1, eb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((nb, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments + n_pad, f),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((nb, 1), jnp.float32)],
        interpret=interpret,
    )(x, src.reshape(1, e + e_pad),
      dst.reshape(1, e + e_pad), scale.reshape(1, e + e_pad))
    return out[:num_segments]
