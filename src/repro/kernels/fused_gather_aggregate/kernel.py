"""Pallas TPU kernel: fused gather -> phi -> aggregate over packed COO.

GNNBuilder's core dataflow claim (paper SV-A, Fig. 3) is that messages
*stream* through the gather -> phi -> aggregate pipeline instead of being
materialized. The `segment_aggregate` kernel (PR 2) fused only the
aggregate stage: every conv still wrote an (E, F) message tensor to HBM
via `jnp.take` before reducing it. This kernel closes that seam for the
linear-phi family (GCN / SAGE / GIN-without-edge-MLP): it consumes the
node-feature table (N, F) plus the raw `src`/`dst` edge-id streams and an
optional per-edge scale (the GCN 1/sqrt(d_u d_v) norm), gathers source
rows *inside* the edge-block loop, and folds them straight into the VMEM
node accumulator — the (E, F) message tensor never touches HBM.

Grid: (node_tiles, edge_tiles) — the edge axis is innermost/sequential,
so each node tile's accumulator persists in VMEM across the whole edge
stream (same schedule as `segment_aggregate`). Block shapes:
  x     (N, F)   — the full node-feature table, resident across steps
  src   (1, EB)  — source node ids (-1 = padding, gathers a zero row)
  dst   (1, EB)  — destination ids (-1 = padding, matches no node row)
  scale (1, EB)  — per-edge message scale (1.0 when unused, 0 on padding)
  out   (NB, F)  — this node tile's aggregate (revisited across j)
Scratch: count (NB, 1).

The gather itself is routed through the MXU: a (N, EB) source one-hot
(with the edge scale folded in, so phi costs nothing extra) contracted
against the node table yields the edge block's scaled messages without a
serial gather loop; the scatter side reuses the segment kernel's
destination one-hot matmul / fori-loop updates.

The node table is dtype-polymorphic: fp32, bf16, or int8 tables stay
resident in VMEM at their storage width — the PrecisionPolicy bandwidth
lever for the gather stage — and the gather contraction + every
accumulator run in fp32 (int8 values are integer-valued fp32, so the
accumulation is exact int32-style). For int8 tables the per-tensor
dequantization scale is folded into the per-edge ``scale`` stream by the
caller (core.aggregations), so dequantization also costs nothing extra.

Supported: sum, mean, min, max — the family GCN/SAGE/GIN lower to.
var/std (PNA towers) and per-edge MLPs keep the materialized path.

Two generations live here (docs/KERNELS.md has the full contract):

* ``fused_gather_aggregate_pallas`` — the **legacy one-hot** gather
  (``gather_mode="onehot"``): the (N, EB) source one-hot contraction
  routes the gather through the MXU, costing O(N * EB * F) MACs per
  edge block and re-sweeping the edge stream once per node tile.
* ``fused_gather_aggregate_v2_pallas`` — the **DMA gather**
  (``gather_mode="dma"``, the default): the src/dst id streams are
  scalar-prefetched into SMEM (PrefetchScalarGridSpec), node rows are
  gathered by dynamic slice, and the per-edge scale stream is
  double-buffered HBM->VMEM by explicit async copies — O(EB * F) work
  per edge block, one sweep over the edge stream, no one-hot ever
  materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

AGGS = ("sum", "mean", "min", "max")


def _fused_kernel(x_ref, src_ref, dst_ref, scale_ref, out_ref, cnt_ref, *,
                  agg: str, edge_steps: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nb = out_ref.shape[0]
    n_src = x_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        if agg in ("sum", "mean"):
            out_ref[...] = jnp.zeros_like(out_ref)
        elif agg == "min":
            out_ref[...] = jnp.full(out_ref.shape, jnp.inf, out_ref.dtype)
        else:
            out_ref[...] = jnp.full(out_ref.shape, -jnp.inf, out_ref.dtype)

    # gather prologue: (N, EB) source one-hot with the per-edge scale
    # folded in, contracted against the node table on the MXU. Padding
    # edges (src == -1) match no row and gather an all-zero message.
    node_rows = jax.lax.broadcasted_iota(jnp.int32, (n_src, 1), 0)
    src_onehot = (src_ref[...] == node_rows).astype(jnp.float32) \
        * scale_ref[...].astype(jnp.float32)
    msg = jax.lax.dot_general(
        src_onehot, x_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (EB, F)

    # (NB, EB) edge->node assignment for this tile pair; padding edges
    # carry dst == -1 and match no node row.
    node_ids = i * nb + jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    onehot = dst_ref[...] == node_ids

    if agg in ("sum", "mean"):
        onef = onehot.astype(jnp.float32)
        out_ref[...] += jnp.dot(onef, msg,
                                preferred_element_type=jnp.float32)
        cnt_ref[...] += jnp.sum(onef, axis=1, keepdims=True)
    else:
        # vectorized masked scatter: broadcast the (NB, EB) assignment
        # over the feature axis and reduce the edge axis in one VPU
        # expression — unassigned (node, edge) pairs contribute the
        # neutral element, so the whole block folds in at once instead
        # of a per-edge serial fori_loop
        neutral = jnp.inf if agg == "min" else -jnp.inf
        masked = jnp.where(onehot[:, :, None], msg[None], neutral)
        blk = masked.min(axis=1) if agg == "min" else masked.max(axis=1)
        out_ref[...] = jnp.minimum(out_ref[...], blk) if agg == "min" \
            else jnp.maximum(out_ref[...], blk)
        cnt_ref[...] += jnp.sum(onehot.astype(jnp.float32), axis=1,
                                keepdims=True)

    @pl.when(j == edge_steps - 1)
    def _finalize():
        if agg == "mean":
            out_ref[...] = out_ref[...] / jnp.maximum(cnt_ref[...], 1.0)
        elif agg in ("min", "max"):
            o = out_ref[...]
            out_ref[...] = jnp.where(jnp.isfinite(o), o, 0.0)


def fused_gather_aggregate_pallas(x, src, dst, num_segments: int, *,
                                  scale=None, agg: str = "sum",
                                  edge_block: int = 128,
                                  node_block: int = 128,
                                  interpret: bool = True):
    """x: (N, F) node features in fp32, bf16, or int8 (the table streams
    and stays VMEM-resident at its storage width; accumulation is fp32);
    src/dst: (E,) int32 endpoint id streams of the packed COO edge
    buffer (-1 or any out-of-range id = padding); scale: optional (E,)
    per-edge message scale (phi), applied before aggregation — int8
    callers fold the dequant scale in here. Returns (num_segments, F)
    float32 aggregates; empty segments zero-fill. The (E, F) message
    tensor is never materialized.
    """
    assert agg in AGGS, agg
    n_src, f = x.shape
    e = src.shape[0]
    if e == 0 or num_segments == 0:
        return jnp.zeros((num_segments, f), jnp.float32)
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    # out-of-range ids (packed-batch overflow bucket, -1 padding) are
    # normalized to -1 on *both* streams so a bad edge neither gathers
    # nor scatters
    bad = (src < 0) | (src >= n_src) | (dst < 0) | (dst >= num_segments)
    src = jnp.where(bad, -1, src)
    dst = jnp.where(bad, -1, dst)
    if scale is None:
        scale = jnp.ones((e,), jnp.float32)
    scale = jnp.where(bad, 0.0, scale.astype(jnp.float32))
    eb = min(edge_block, e)
    nb = min(node_block, num_segments)
    e_pad = (-e) % eb
    n_pad = (-num_segments) % nb
    if e_pad:
        src = jnp.pad(src, (0, e_pad), constant_values=-1)
        dst = jnp.pad(dst, (0, e_pad), constant_values=-1)
        scale = jnp.pad(scale, (0, e_pad))
    grid = ((num_segments + n_pad) // nb, (e + e_pad) // eb)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, agg=agg, edge_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src, f), lambda i, j: (0, 0)),
            pl.BlockSpec((1, eb), lambda i, j: (0, j)),
            pl.BlockSpec((1, eb), lambda i, j: (0, j)),
            pl.BlockSpec((1, eb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((nb, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments + n_pad, f),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((nb, 1), jnp.float32)],
        interpret=interpret,
    )(x, src.reshape(1, e + e_pad),
      dst.reshape(1, e + e_pad), scale.reshape(1, e + e_pad))
    return out[:num_segments]


# ------------------------------------------------------------ gather v2 --
def _v2_kernel(src_ref, dst_ref, x_ref, scale_hbm, out_ref, sbuf, sems,
               cnt_ref, *, agg: str, edge_steps: int, eb: int,
               track_count: bool):
    """One grid step folds one edge block into the resident accumulator.

    src_ref/dst_ref are the *whole* id streams in SMEM (scalar prefetch);
    scale_hbm stays in HBM (memory_space=ANY) and is copied in one edge
    block ahead of compute through the two-slot ``sbuf`` VMEM scratch —
    the double-buffered HBM->VMEM edge pipeline. x_ref and out_ref are
    whole-table VMEM residents."""
    j = pl.program_id(0)

    def dma(slot, step):
        return pltpu.make_async_copy(
            scale_hbm.at[:, pl.ds(step * eb, eb)], sbuf.at[slot],
            sems.at[slot])

    @pl.when(j == 0)
    def _init():
        if track_count:
            cnt_ref[...] = jnp.zeros_like(cnt_ref)
        if agg in ("sum", "mean"):
            out_ref[...] = jnp.zeros_like(out_ref)
        elif agg == "min":
            out_ref[...] = jnp.full(out_ref.shape, jnp.inf, out_ref.dtype)
        else:
            out_ref[...] = jnp.full(out_ref.shape, -jnp.inf, out_ref.dtype)
        dma(0, 0).start()

    slot = jax.lax.rem(j, 2)

    @pl.when(j + 1 < edge_steps)
    def _prefetch_next():
        dma(1 - slot, j + 1).start()

    dma(slot, j).wait()

    base = j * eb

    def body(e, _):
        s = src_ref[base + e]
        d = dst_ref[base + e]
        sl = jnp.maximum(s, 0)
        dl = jnp.maximum(d, 0)
        sc = sbuf[slot, 0, e]
        row = x_ref[pl.ds(sl, 1), :].astype(jnp.float32) * sc
        cur = out_ref[pl.ds(dl, 1), :]
        if agg in ("sum", "mean"):
            # padding edges carry scale == 0: they add a zero row at the
            # clamped slot, so no validity select is needed on this path
            out_ref[pl.ds(dl, 1), :] = cur + row
        else:
            ok = d >= 0
            upd = jnp.minimum(cur, row) if agg == "min" \
                else jnp.maximum(cur, row)
            out_ref[pl.ds(dl, 1), :] = jnp.where(ok, upd, cur)
        if track_count:
            c = cnt_ref[pl.ds(dl, 1), :]
            cnt_ref[pl.ds(dl, 1), :] = c + jnp.where(d >= 0, 1.0, 0.0)
        return 0

    jax.lax.fori_loop(0, eb, body, 0)

    @pl.when(j == edge_steps - 1)
    def _finalize():
        if agg == "mean":
            out_ref[...] = out_ref[...] / jnp.maximum(cnt_ref[...], 1.0)
        elif agg in ("min", "max"):
            o = out_ref[...]
            out_ref[...] = jnp.where(jnp.isfinite(o), o, 0.0)


def fused_gather_aggregate_v2_pallas(x, src, dst, num_segments: int, *,
                                     scale=None, agg: str = "sum",
                                     edge_block: int = 128,
                                     node_block: int = 128,
                                     interpret: bool = True):
    """One-hot-free fused gather (``gather_mode="dma"``, the default).

    Same contract as ``fused_gather_aggregate_pallas`` — x: (N, F) node
    table in fp32/bf16/int8 (VMEM-resident at storage width, fp32
    accumulation); src/dst: (E,) int32 with -1/out-of-range = padding;
    scale: optional (E,) per-edge phi (int8 dequant folds in here);
    returns (num_segments, F) float32, empty segments zero-fill — but a
    different machine: the id streams ride in SMEM via scalar prefetch,
    each source row is gathered by dynamic slice (O(EB * F) per edge
    block instead of the one-hot's O(N * EB * F)), the scale stream is
    double-buffered HBM->VMEM by explicit async copies, and the whole
    (num_segments, F) accumulator is VMEM-resident, so the edge stream
    is swept exactly once (``node_block`` is accepted for knob
    compatibility and ignored).

    Grid: (edge_tiles,). Scratch: two-slot (2, 1, EB) scale buffer + a
    DMA semaphore pair + the mean path's (num_segments, 1) count column.
    """
    assert agg in AGGS, agg
    del node_block                       # v2 keeps the whole table
    n_src, f = x.shape
    e = src.shape[0]
    if e == 0 or num_segments == 0:
        return jnp.zeros((num_segments, f), jnp.float32)
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    bad = (src < 0) | (src >= n_src) | (dst < 0) | (dst >= num_segments)
    src = jnp.where(bad, -1, src)
    dst = jnp.where(bad, -1, dst)
    if scale is None:
        scale = jnp.ones((e,), jnp.float32)
    scale = jnp.where(bad, 0.0, scale.astype(jnp.float32))
    eb = min(edge_block, e)
    e_pad = (-e) % eb
    if e_pad:
        src = jnp.pad(src, (0, e_pad), constant_values=-1)
        dst = jnp.pad(dst, (0, e_pad), constant_values=-1)
        scale = jnp.pad(scale, (0, e_pad))
    steps = (e + e_pad) // eb
    track_count = agg == "mean"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((n_src, f), lambda j, s_r, d_r: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),      # scale stays HBM
        ],
        out_specs=pl.BlockSpec((num_segments, f),
                               lambda j, s_r, d_r: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, 1, eb), jnp.float32),       # two-slot buffer
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((num_segments if track_count else 8, 1),
                       jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_v2_kernel, agg=agg, edge_steps=steps, eb=eb,
                          track_count=track_count),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, f), jnp.float32),
        interpret=interpret,
    )(src, dst, x, scale.reshape(1, e + e_pad))
    return out
