"""jit'd public wrapper for fused_gather_aggregate."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_gather_aggregate.kernel import (
    fused_gather_aggregate_pallas, fused_gather_aggregate_v2_pallas)
from repro.kernels.fused_gather_aggregate.ref import (
    fused_gather_aggregate_ref, fused_gather_aggregate_v2_ref)

GATHER_MODES = ("onehot", "dma")


@partial(jax.jit, static_argnames=("num_segments", "agg", "edge_block",
                                   "node_block", "use_pallas", "interpret",
                                   "gather_mode"))
def fused_gather_aggregate(x, src, dst, valid=None, scale=None, *,
                           num_segments: int, agg: str = "sum",
                           edge_block: int = 128, node_block: int = 128,
                           use_pallas: bool = True, interpret: bool = True,
                           gather_mode: str = "dma"):
    """Gather source-node rows and aggregate them per destination segment
    in one fused pass — the (E, F) message tensor never reaches HBM.

    x (N, F) — fp32, bf16, or int8; the table streams and stays
    VMEM-resident at its storage width, accumulation is fp32 (int8
    callers fold the per-tensor dequant scale into ``scale``, see
    ``core.aggregations.gather_aggregate(precision=...)``); src/dst (E,)
    int32 endpoint id streams of the packed COO edge buffer, with
    padding marked by -1, any out-of-range id, or ``valid == False``;
    scale: optional (E,) per-edge message scale (the GCN symmetric
    norm). Returns (num_segments, F) float32.

    gather_mode selects the kernel generation: "dma" (default) is the
    one-hot-free v2 kernel — scalar-prefetched id streams, dynamic-slice
    gather, double-buffered scale copies, O(EB * F) per edge block;
    "onehot" is the legacy (N, EB) one-hot MXU contraction kept for
    comparison and DSE featurization (docs/KERNELS.md).

    use_pallas=False falls back to the matching pure-jnp mirror oracle
    (ref.py) — a testing aid whose dense (N, E) / (S, E, F)
    intermediates do not scale to production buffers. The production
    fallback under pjit is
    ``core.aggregations.gather_aggregate(backend="xla")``, which
    materializes the messages and segment-reduces them."""
    if gather_mode not in GATHER_MODES:
        raise ValueError(f"unknown gather_mode {gather_mode!r}; expected "
                         f"one of {GATHER_MODES}")
    src = src.astype(jnp.int32)
    if valid is not None:
        src = jnp.where(valid, src, -1)
    if use_pallas:
        kern = fused_gather_aggregate_v2_pallas if gather_mode == "dma" \
            else fused_gather_aggregate_pallas
        return kern(x, src, dst, num_segments, scale=scale, agg=agg,
                    edge_block=edge_block, node_block=node_block,
                    interpret=interpret)
    ref = fused_gather_aggregate_v2_ref if gather_mode == "dma" \
        else fused_gather_aggregate_ref
    return ref(x, src, dst, num_segments, scale=scale, agg=agg)
