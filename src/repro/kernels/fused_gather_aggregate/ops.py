"""jit'd public wrapper for fused_gather_aggregate."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_gather_aggregate.kernel import (
    fused_gather_aggregate_pallas)
from repro.kernels.fused_gather_aggregate.ref import (
    fused_gather_aggregate_ref)


@partial(jax.jit, static_argnames=("num_segments", "agg", "edge_block",
                                   "node_block", "use_pallas", "interpret"))
def fused_gather_aggregate(x, src, dst, valid=None, scale=None, *,
                           num_segments: int, agg: str = "sum",
                           edge_block: int = 128, node_block: int = 128,
                           use_pallas: bool = True, interpret: bool = True):
    """Gather source-node rows and aggregate them per destination segment
    in one fused pass — the (E, F) message tensor never reaches HBM.

    x (N, F) — fp32, bf16, or int8; the table streams and stays
    VMEM-resident at its storage width, accumulation is fp32 (int8
    callers fold the per-tensor dequant scale into ``scale``, see
    ``core.aggregations.gather_aggregate(precision=...)``); src/dst (E,)
    int32 endpoint id streams of the packed COO edge buffer, with
    padding marked by -1, any out-of-range id, or ``valid == False``;
    scale: optional (E,) per-edge message scale (the GCN symmetric
    norm). Returns (num_segments, F) float32.

    use_pallas=False falls back to the pure-jnp mirror oracle (ref.py) —
    a testing aid whose dense (N, E) / (N, E, F) intermediates do not
    scale to production buffers. The production fallback under pjit is
    ``core.aggregations.gather_aggregate(backend="xla")``, which
    materializes the messages and segment-reduces them."""
    src = src.astype(jnp.int32)
    if valid is not None:
        src = jnp.where(valid, src, -1)
    if use_pallas:
        return fused_gather_aggregate_pallas(
            x, src, dst, num_segments, scale=scale, agg=agg,
            edge_block=edge_block, node_block=node_block,
            interpret=interpret)
    return fused_gather_aggregate_ref(x, src, dst, num_segments,
                                      scale=scale, agg=agg)
