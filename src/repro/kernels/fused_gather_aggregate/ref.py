"""Pure-jnp oracle for the fused_gather_aggregate kernel.

Mirrors the kernel's math over the full edge stream at once: the same
(N_src, E) scaled source one-hot contraction performs the gather+phi, and
the same (num_segments, E) destination one-hot performs the scatter —
identical masking rules (out-of-range ids on either stream kill the whole
edge), identical neutral elements, identical zero-fill for empty
segments.
"""
from __future__ import annotations

import jax.numpy as jnp


def fused_gather_aggregate_ref(x, src, dst, num_segments: int, *,
                               scale=None, agg: str = "sum"):
    """x: (N, F) in any dtype the kernel accepts (fp32 / bf16 / int8 —
    values pass through ``astype(float32)`` exactly, mirroring the
    kernel's fp32 gather contraction; int8 callers fold the dequant
    scale into ``scale``); src/dst: (E,) int32 (-1 / out-of-range =
    padding); scale: optional (E,) -> (num_segments, F) float32."""
    xf = x.astype(jnp.float32)
    n_src, _ = xf.shape
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    bad = (src < 0) | (src >= n_src) | (dst < 0) | (dst >= num_segments)
    src = jnp.where(bad, -1, src)
    dst = jnp.where(bad, -1, dst)
    if scale is None:
        scale = jnp.ones(src.shape, jnp.float32)
    scale = jnp.where(bad, 0.0, scale.astype(jnp.float32))
    # gather + phi: (N_src, E) scaled one-hot contracted with the table
    rows = jnp.arange(n_src, dtype=jnp.int32)[:, None]
    src_onehot = (src[None, :] == rows).astype(jnp.float32) * scale[None, :]
    msg = src_onehot.T @ xf                           # (E, F)
    # scatter: (num_segments, E) destination one-hot
    node_ids = jnp.arange(num_segments, dtype=jnp.int32)[:, None]
    onehot = dst[None, :] == node_ids
    onef = onehot.astype(jnp.float32)
    cnt = onef.sum(1, keepdims=True)
    if agg == "sum":
        return onef @ msg
    if agg == "mean":
        return (onef @ msg) / jnp.maximum(cnt, 1.0)
    if agg in ("min", "max"):
        neutral = jnp.inf if agg == "min" else -jnp.inf
        masked = jnp.where(onehot[:, :, None], msg[None], neutral)
        out = masked.min(1) if agg == "min" else masked.max(1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(agg)


def fused_gather_aggregate_v2_ref(x, src, dst, num_segments: int, *,
                                  scale=None, agg: str = "sum"):
    """Oracle for the v2 (one-hot-free) kernel: indexed gather of the
    clamped source ids — the dense mirror of the kernel's per-edge
    dynamic-slice gather — then per-destination masked reductions over
    the full edge stream. Same normalization as the kernel wrapper
    (out-of-range ids on either stream kill the whole edge; padding
    gathers a zero row via scale == 0), same neutral elements, same
    zero-fill for empty segments. Same arguments and results as
    ``fused_gather_aggregate_ref``; only the gather machinery differs.
    """
    xf = x.astype(jnp.float32)
    n_src, _ = xf.shape
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    bad = (src < 0) | (src >= n_src) | (dst < 0) | (dst >= num_segments)
    src = jnp.where(bad, -1, src)
    dst = jnp.where(bad, -1, dst)
    if scale is None:
        scale = jnp.ones(src.shape, jnp.float32)
    scale = jnp.where(bad, 0.0, scale.astype(jnp.float32))
    rows = jnp.take(xf, jnp.maximum(src, 0), axis=0) \
        * scale[:, None]                              # (E, F)
    node_ids = jnp.arange(num_segments, dtype=jnp.int32)[:, None]
    onehot = dst[None, :] == node_ids                 # (S, E)
    cnt = onehot.astype(jnp.float32).sum(1, keepdims=True)
    if agg == "sum":
        return jnp.where(onehot[:, :, None], rows[None], 0.0).sum(1)
    if agg == "mean":
        s = jnp.where(onehot[:, :, None], rows[None], 0.0).sum(1)
        return s / jnp.maximum(cnt, 1.0)
    if agg in ("min", "max"):
        neutral = jnp.inf if agg == "min" else -jnp.inf
        masked = jnp.where(onehot[:, :, None], rows[None], neutral)
        out = masked.min(1) if agg == "min" else masked.max(1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(agg)
