"""Pallas TPU kernel: multi-layer VMEM-resident conv stack.

The packed GraphBatch IR makes layer boundaries explicit, so for the
linear-phi family (GCN / SAGE without edge features) consecutive conv
layers can run back-to-back *inside one kernel*: the node-feature table
is written to VMEM once, every layer's gather -> aggregate -> transform
-> skip -> activation executes against the resident table, and HBM sees
the table exactly twice (initial copy-in, final copy-out) instead of
twice **per layer** — the inter-layer on-chip reuse lever of the
GNN-acceleration survey (PAPERS.md, 2306.14052), and the TPU analogue of
keeping the embedding BRAM hot across the paper's pipelined layers.

Grid: (layers, edge_tiles) — the edge axis is innermost/sequential, so
each layer sweeps the whole edge stream before the next layer's grid
steps begin. Blocks:
  x0     (N, Fmax)      — initial node table, read once (copy-in at
                          step (0, 0))
  scale  (1, EB)        — per-edge phi for this step (GCN norm / SAGE
                          validity; 0 on padding)
  sv     (N, 1)         — GCN self-loop scale (unused for SAGE)
  mask   (N, 1)         — node validity column
  w_a/w_n/w_skip (1, Fmax, Fmax), b (1, 1, Fmax) — layer i's stacked
                          zero-padded weights (skip: identity when the
                          dims match, the projection when they differ,
                          zeros when skips are off); Pallas streams the
                          per-layer blocks double-buffered
  qp     (1, 128)       — layer i's precision row [mode, s, lo, hi, ...]
  out    (N, Fmax)      — the resident table, revisited by every step
Scratch: aggr (N, Fmax) accumulator, count column (mean only), and the
quantized-table shadow xq (non-fp32 policies only).

Per-layer math (the exact ``core.convs`` aggregate-first forms):
  GCN:  h = round((aggr + xq * sv)) @ W + b
  SAGE: h = round(xq) @ W_self + b_self + round(aggr) @ W_neigh
then h (+ skip from the *fp32* table) -> activation -> node mask, and
the result overwrites the resident table for the next layer. ``round``
/ ``xq`` emulate the per-layer PrecisionPolicy dynamically from the qp
row: mode 0 = fp32 identity, 1 = bf16 rounding, 2 = int8 fake-quant
(``clip(round(x / s) * s)`` — exactly ``quantization.quantize``; the
shadow table stores grid values at fp32 emulation width, as the XLA
path does). Zero-padded weight columns keep padded feature columns from
ever leaking into real ones, so the caller just slices the final table.

Padding edges (src == -1 after normalization) carry scale == 0 and are
excluded from the mean count; min/max are not needed here (GCN lowers
to sum, SAGE to mean), so the accumulator is a plain fp32 add.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.nn.layers import act

RESIDENT_KINDS = ("gcn", "sage")
RESIDENT_AGGS = {"gcn": "sum", "sage": "mean"}

# qp-row precision modes (matching quantization.PRECISIONS order)
_MODE_FP32, _MODE_BF16, _MODE_INT8 = 0.0, 1.0, 2.0


def _cast_dyn(x, qp):
    """Dynamic ``LayerPrecision.cast_activation``: qp = [mode, s, lo, hi].
    All three candidates are cheap VPU expressions; ``where`` selects the
    layer's mode at run time so one kernel serves mixed-precision
    stacks."""
    mode, s, lo, hi = qp[0], qp[1], qp[2], qp[3]
    bf = x.astype(jnp.bfloat16).astype(jnp.float32)
    safe_s = jnp.maximum(s, 1e-30)
    i8 = jnp.clip(jnp.round(x / safe_s) * safe_s, lo, hi)
    return jnp.where(mode == _MODE_BF16, bf,
                     jnp.where(mode == _MODE_INT8, i8, x))


def _round_in(x, qp):
    """Dynamic mirror of ``aggr.astype(x_in.dtype)`` before the conv
    matmul: bf16 rounds, fp32/int8 (fake-quant values live in fp32) pass
    through."""
    bf = x.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.where(qp[0] == _MODE_BF16, bf, x)


def _stack_kernel(src_ref, dst_ref, x0_ref, scale_ref, sv_ref, mask_ref,
                  wa_ref, wn_ref, wsk_ref, b_ref, qp_ref, xout_ref,
                  aggr_ref, cnt_ref, xq_ref, *, kind: str,
                  activation: str, edge_steps: int, eb: int,
                  has_skip: bool, quantized: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)
    track_count = RESIDENT_AGGS[kind] == "mean"

    @pl.when((i == 0) & (j == 0))
    def _copy_in():
        xout_ref[...] = x0_ref[...].astype(jnp.float32)

    @pl.when(j == 0)
    def _layer_init():
        aggr_ref[...] = jnp.zeros_like(aggr_ref)
        if track_count:
            cnt_ref[...] = jnp.zeros_like(cnt_ref)
        if quantized:
            xq_ref[...] = _cast_dyn(xout_ref[...], qp_ref[0])

    table_ref = xq_ref if quantized else xout_ref
    base = j * eb

    def body(e, _):
        s = src_ref[base + e]
        d = dst_ref[base + e]
        sl = jnp.maximum(s, 0)
        dl = jnp.maximum(d, 0)
        sc = scale_ref[0, e]
        row = table_ref[pl.ds(sl, 1), :] * sc
        aggr_ref[pl.ds(dl, 1), :] += row     # padding: scale == 0
        if track_count:
            c = cnt_ref[pl.ds(dl, 1), :]
            cnt_ref[pl.ds(dl, 1), :] = c + jnp.where(d >= 0, 1.0, 0.0)
        return 0

    jax.lax.fori_loop(0, eb, body, 0)

    @pl.when(j == edge_steps - 1)
    def _layer_boundary():
        qp = qp_ref[0]
        aggr = aggr_ref[...]
        if track_count:
            aggr = aggr / jnp.maximum(cnt_ref[...], 1.0)
        xq = table_ref[...]
        w_n = wn_ref[0]
        bias = b_ref[0]
        if kind == "gcn":
            t = _round_in(aggr + xq * sv_ref[...], qp)
            h = jnp.dot(t, w_n, preferred_element_type=jnp.float32) + bias
        else:                                # sage
            h = jnp.dot(_round_in(xq, qp), wa_ref[0],
                        preferred_element_type=jnp.float32) + bias \
                + jnp.dot(_round_in(aggr, qp), w_n,
                          preferred_element_type=jnp.float32)
        h = _round_in(h, qp)                 # conv output at compute width
        if has_skip:
            # skips run on the fp32 residual stream (pre-cast table)
            h = h + jnp.dot(xout_ref[...], wsk_ref[0],
                            preferred_element_type=jnp.float32)
        xout_ref[...] = act(activation)(h) * mask_ref[...]


def fused_layer_stack_pallas(x, src, dst, scale, self_vec, node_mask,
                             w_a, w_n, w_skip, b, qp, *, kind: str,
                             activation: str = "relu",
                             edge_block: int = 128,
                             interpret: bool = True,
                             has_skip: bool = True,
                             quantized: bool = False):
    """Run ``K = w_n.shape[0]`` consecutive conv layers with the node
    table VMEM-resident. x: (N, Fmax) fp32 zero-padded table; src/dst:
    (E,) int32 (-1 / out-of-range = padding); scale: (E,) per-edge phi;
    self_vec / node_mask: (N, 1) fp32; w_a/w_n/w_skip: (K, Fmax, Fmax)
    zero-padded stacks, b: (K, Fmax); qp: (K, >=4) per-layer precision
    rows [mode, s, lo, hi]. Returns the (N, Fmax) fp32 table after the
    last layer (callers slice to the final out_dim)."""
    if kind not in RESIDENT_KINDS:
        raise ValueError(f"resident stack supports {RESIDENT_KINDS}, "
                         f"got {kind!r}")
    n, fmax = x.shape
    k = w_n.shape[0]
    e = src.shape[0]
    src = jnp.asarray(src).astype(jnp.int32)
    dst = jnp.asarray(dst).astype(jnp.int32)
    bad = (src < 0) | (src >= n) | (dst < 0) | (dst >= n)
    src = jnp.where(bad, -1, src)
    dst = jnp.where(bad, -1, dst)
    scale = jnp.where(bad, 0.0, scale.astype(jnp.float32))
    eb = min(edge_block, max(e, 1))
    e_pad = (-e) % eb if e else eb
    if e_pad:
        src = jnp.pad(src, (0, e_pad), constant_values=-1)
        dst = jnp.pad(dst, (0, e_pad), constant_values=-1)
        scale = jnp.pad(scale, (0, e_pad))
    steps = (e + e_pad) // eb
    qp_pad = jnp.zeros((k, 128), jnp.float32).at[:, :qp.shape[1]].set(
        qp.astype(jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(k, steps),
        in_specs=[
            pl.BlockSpec((n, fmax), lambda i, j, s_r, d_r: (0, 0)),
            pl.BlockSpec((1, eb), lambda i, j, s_r, d_r: (0, j)),
            pl.BlockSpec((n, 1), lambda i, j, s_r, d_r: (0, 0)),
            pl.BlockSpec((n, 1), lambda i, j, s_r, d_r: (0, 0)),
            pl.BlockSpec((1, fmax, fmax), lambda i, j, s_r, d_r: (i, 0, 0)),
            pl.BlockSpec((1, fmax, fmax), lambda i, j, s_r, d_r: (i, 0, 0)),
            pl.BlockSpec((1, fmax, fmax), lambda i, j, s_r, d_r: (i, 0, 0)),
            pl.BlockSpec((1, fmax), lambda i, j, s_r, d_r: (i, 0)),
            pl.BlockSpec((1, 128), lambda i, j, s_r, d_r: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, fmax), lambda i, j, s_r, d_r: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n, fmax), jnp.float32),
            pltpu.VMEM((n if RESIDENT_AGGS[kind] == "mean" else 8, 1),
                       jnp.float32),
            pltpu.VMEM((n, fmax) if quantized else (8, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_stack_kernel, kind=kind, activation=activation,
                          edge_steps=steps, eb=eb, has_skip=has_skip,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, fmax), jnp.float32),
        interpret=interpret,
    )(src, dst, x.astype(jnp.float32),
      scale.reshape(1, e + e_pad),
      self_vec.astype(jnp.float32).reshape(n, 1),
      node_mask.astype(jnp.float32).reshape(n, 1),
      w_a.astype(jnp.float32), w_n.astype(jnp.float32),
      w_skip.astype(jnp.float32), b.astype(jnp.float32), qp_pad)
