"""Pure-jnp oracle for the gnn_aggregate kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gnn_aggregate_ref(x, nbr, *, agg: str = "sum"):
    """x: (N, F); nbr: (N, K) int32 with -1 padding -> (N, F)."""
    xf = x.astype(jnp.float32)
    valid = (nbr >= 0)[..., None]                        # (N, K, 1)
    rows = jnp.take(xf, jnp.maximum(nbr, 0), axis=0)     # (N, K, F)
    vf = valid.astype(jnp.float32)
    cnt = vf.sum(1)                                      # (N, 1)
    if agg == "sum":
        out = (rows * vf).sum(1)
    elif agg == "mean":
        out = (rows * vf).sum(1) / jnp.maximum(cnt, 1.0)
    elif agg == "min":
        out = jnp.where(valid, rows, jnp.inf).min(1)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    elif agg == "max":
        out = jnp.where(valid, rows, -jnp.inf).max(1)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    elif agg in ("var", "std"):
        # two-pass form: numerically matches Welford (E[x^2]-E[x]^2 loses
        # precision to cancellation and diverges from the kernel)
        c = jnp.maximum(cnt, 1.0)
        mu = (rows * vf).sum(1) / c
        var = (jnp.square(rows - mu[:, None]) * vf).sum(1) / c
        var = jnp.maximum(var, 1e-12)
        out = jnp.sqrt(var) if agg == "std" else var
    else:
        raise ValueError(agg)
    return out.astype(x.dtype)


def neighbor_table(edge_index, num_nodes: int, k_max: int):
    """Padded (N, K) neighbor table from COO (the paper's neighbor +
    offset tables, densified). Pure-numpy host-side preprocessing."""
    import numpy as np
    nbr = np.full((num_nodes, k_max), -1, np.int32)
    fill = np.zeros(num_nodes, np.int32)
    for s, d in np.asarray(edge_index):
        if s < 0 or d < 0 or d >= num_nodes:
            continue
        if fill[d] < k_max:
            nbr[d, fill[d]] = s
            fill[d] += 1
    return nbr
