"""Pallas TPU kernel: fused neighbor gather + single-pass aggregation
over the *legacy padded (N, K) neighbor-table layout*.

The paper's message-passing engine (Fig. 3) keeps the node-embedding table
in BRAM and streams each node's neighbor block through phi->partial-agg.
TPU adaptation: MAX_NODES-bounded molecular graphs fit the full embedding
table in VMEM (600 x 256 fp32 = 0.6 MB), so the kernel pins the table and
iterates a *padded neighbor table* (N, K) — the CSR neighbor/offset pair
recast as a dense structure XLA-style static shapes want. Aggregations are
the paper's O(1)-state single-pass forms, including Welford var/std.

Note: the hot path no longer runs through this layout. Packed GraphBatch
inference (DESIGN_BATCHING.md) lowers every conv through
``core.aggregations.segment_aggregate`` over flat COO edge streams, whose
fused Pallas form lives in ``kernels/segment_aggregate`` behind the
``backend="xla"|"pallas"`` switch. This kernel remains for single padded
graphs whose neighbor lists are already densified.

Grid: (node_tiles,). Block shapes:
  x        (N, F)  — full table, VMEM-pinned (BRAM analogue)
  nbr      (BN, K) — this tile's neighbor indices (-1 = padding)
  out      (BN, F)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

AGGS = ("sum", "mean", "min", "max", "var", "std")


def _agg_kernel(x_ref, nbr_ref, out_ref, *, agg: str, k_max: int):
    x = x_ref[...]                       # (N, F) resident table
    nbr = nbr_ref[...]                   # (BN, K)
    bn, _ = nbr.shape
    f = x.shape[1]

    def body(k, state):
        idx = nbr[:, k]                          # (BN,)
        valid = (idx >= 0)[:, None]              # (BN, 1)
        rows = jnp.take(x, jnp.maximum(idx, 0), axis=0)  # (BN, F)
        vf = valid.astype(jnp.float32)
        if agg in ("sum", "mean"):
            acc, cnt = state
            return acc + rows * vf, cnt + vf
        if agg == "min":
            acc, cnt = state
            return jnp.where(valid, jnp.minimum(acc, rows), acc), cnt + vf
        if agg == "max":
            acc, cnt = state
            return jnp.where(valid, jnp.maximum(acc, rows), acc), cnt + vf
        # Welford single-pass (paper §V-B): O(1) state per node
        mean, m2, cnt = state
        cnt_new = cnt + vf
        safe = jnp.maximum(cnt_new, 1.0)
        delta = rows - mean
        mean_new = mean + jnp.where(valid, delta / safe, 0.0)
        m2_new = m2 + jnp.where(valid, delta * (rows - mean_new), 0.0)
        return mean_new, m2_new, cnt_new

    zeros = jnp.zeros((bn, f), jnp.float32)
    cnt0 = jnp.zeros((bn, 1), jnp.float32)
    if agg in ("sum", "mean"):
        init = (zeros, cnt0)
    elif agg == "min":
        init = (jnp.full((bn, f), jnp.inf, jnp.float32), cnt0)
    elif agg == "max":
        init = (jnp.full((bn, f), -jnp.inf, jnp.float32), cnt0)
    else:
        init = (zeros, zeros, cnt0)

    state = jax.lax.fori_loop(0, k_max, body, init)

    if agg == "sum":
        out = state[0]
    elif agg == "mean":
        out = state[0] / jnp.maximum(state[1], 1.0)
    elif agg in ("min", "max"):
        out = jnp.where(jnp.isfinite(state[0]), state[0], 0.0)
    else:
        var = state[1] / jnp.maximum(state[2], 1.0)
        var = jnp.maximum(var, 1e-12)   # clamp: sqrt'(0) = inf -> NaN grads
        out = jnp.sqrt(var) if agg == "std" else var
    out_ref[...] = out.astype(out_ref.dtype)


def gnn_aggregate_pallas(x, nbr, *, agg: str = "sum", block_nodes: int = 128,
                         interpret: bool = True):
    """x: (N, F) fp32 node table; nbr: (N, K) int32 neighbor table
    (-1 padded). Returns (N, F) aggregated neighbor features."""
    assert agg in AGGS, agg
    n, f = x.shape
    k_max = nbr.shape[1]
    bn = min(block_nodes, n)
    pad = (-n) % bn
    if pad:
        nbr = jnp.pad(nbr, ((0, pad), (0, 0)), constant_values=-1)
    grid = ((n + pad) // bn,)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, agg=agg, k_max=k_max),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, f), lambda i: (0, 0)),      # full table
            pl.BlockSpec((bn, k_max), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, f), x.dtype),
        interpret=interpret,
    )(x, nbr)
    return out[:n]
