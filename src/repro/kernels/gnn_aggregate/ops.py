"""jit'd public wrapper for gnn_aggregate."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.gnn_aggregate.kernel import gnn_aggregate_pallas
from repro.kernels.gnn_aggregate.ref import gnn_aggregate_ref


@partial(jax.jit, static_argnames=("agg", "block_nodes", "use_pallas",
                                   "interpret"))
def gnn_aggregate(x, nbr, *, agg: str = "sum", block_nodes: int = 128,
                  use_pallas: bool = True, interpret: bool = True):
    """Aggregate neighbor embeddings. x (N,F); nbr (N,K) int32 -1-padded.

    use_pallas=False falls back to the XLA reference (the path used under
    pjit; Pallas engages on single-device serving and via shard_map)."""
    if use_pallas:
        return gnn_aggregate_pallas(x, nbr, agg=agg,
                                    block_nodes=block_nodes,
                                    interpret=interpret)
    return gnn_aggregate_ref(x, nbr, agg=agg)
