"""Pure-jnp oracle for the segment_aggregate kernel.

Mirrors the kernel's math over the full (num_segments, E) edge->node
assignment at once: the dense one-hot matmul is the unrolled form of the
kernel's per-edge-block scatter, and var/std use the per-segment-mean
two-pass form, which matches Welford to fp32 tolerance (unlike
E[x^2]-E[x]^2, which loses precision to cancellation).
"""
from __future__ import annotations

import jax.numpy as jnp


def segment_aggregate_ref(messages, seg_ids, num_segments: int, *,
                          agg: str = "sum"):
    """messages: (E, F) in any dtype the kernel accepts (fp32 / bf16 /
    int8 — values pass through ``astype(float32)`` exactly, mirroring
    the kernel's fp32 accumulation); seg_ids: (E,) int32, -1 or
    out-of-range ids are padding -> (num_segments, F) float32."""
    m = messages.astype(jnp.float32)
    seg = seg_ids.astype(jnp.int32)
    node_ids = jnp.arange(num_segments, dtype=jnp.int32)[:, None]
    # -1 / out-of-range padding ids match no node row
    onehot = seg[None, :] == node_ids                # (N, E)
    onef = onehot.astype(jnp.float32)
    cnt = onef.sum(1, keepdims=True)                 # (N, 1)
    s = onef @ m                                     # (N, F)
    if agg == "sum":
        return s
    if agg == "mean":
        return s / jnp.maximum(cnt, 1.0)
    if agg in ("min", "max"):
        neutral = jnp.inf if agg == "min" else -jnp.inf
        masked = jnp.where(onehot[:, :, None], m[None], neutral)
        out = masked.min(1) if agg == "min" else masked.max(1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    if agg in ("var", "std"):
        c = jnp.maximum(cnt, 1.0)
        mu = s / c                                   # (N, F)
        dev = m[None] - mu[:, None]                  # (N, E, F)
        var = jnp.einsum("ne,nef->nf", onef, jnp.square(dev)) / c
        var = jnp.maximum(var, 1e-12)
        return jnp.sqrt(var) if agg == "std" else var
    raise ValueError(agg)
