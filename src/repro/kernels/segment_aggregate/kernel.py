"""Pallas TPU kernel: fused edge-segment aggregation over packed COO.

The packed GraphBatch IR (DESIGN_BATCHING.md) carries edges as a flat COO
stream — messages (E, F) plus per-edge destination segment ids — which is
the layout the paper's message-passing engine (Fig. 3) consumes: a sorted
edge stream driving single-pass partial aggregations (§V-B). This kernel
is the TPU analogue of that datapath for *packed* batches: the node
accumulator table lives in VMEM scratch (the BRAM analogue), the edge
stream is tiled into ``edge_block``-sized chunks, and each grid step folds
one chunk into the table. var/std use Welford's online update, identical
math to the streaming reference in ``core.aggregations``.

Grid: (node_tiles, edge_tiles) — the edge axis is innermost/sequential,
so each node tile's accumulator persists in VMEM across the whole edge
stream. Block shapes:
  msg   (EB, F)  — this step's edge messages
  dst   (1, EB)  — destination segment ids (-1 = padding, never matches)
  out   (NB, F)  — this node tile's aggregate (revisited across j)
Scratch: count (NB, 1) always; Welford mean/M2 (NB, F) for var/std.

The kernel is dtype-polymorphic in the *message tiles*: fp32, bf16, or
int8 blocks move HBM->VMEM at their storage width (the PrecisionPolicy
bandwidth lever), and every accumulator — sum, count, Welford mean/M2 —
is fp32 regardless (int8 sums are integer-valued fp32, i.e. exact
int32-style accumulation). Low-precision inputs are dequantized by the
caller (core.aggregations folds the per-tensor scale onto the output);
the output is always fp32.

Two generations live here, mirroring ``fused_gather_aggregate``
(docs/KERNELS.md has the full contract):

* ``segment_aggregate_pallas`` — the **legacy one-hot** schedule
  (``gather_mode="onehot"``): a (NB, EB) destination one-hot routes the
  scatter through the MXU / a masked VPU reduce, costing O(NB * EB * F)
  per tile pair and re-sweeping the edge stream once per node tile.
* ``segment_aggregate_v2_pallas`` — the **DMA** schedule
  (``gather_mode="dma"``, the default): the dst id stream is
  scalar-prefetched into SMEM (PrefetchScalarGridSpec), message tiles
  are double-buffered HBM->VMEM by explicit async copies at storage
  width, and the whole (num_segments, F) accumulator — including the
  Welford mean/M2 pair for var/std — is VMEM-resident, so the edge
  stream is swept exactly once with no one-hot ever materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

AGGS = ("sum", "mean", "min", "max", "var", "std")


def _seg_kernel(msg_ref, dst_ref, out_ref, *scratch, agg: str,
                edge_steps: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nb, f = out_ref.shape
    eb = msg_ref.shape[0]
    cnt_ref = scratch[0]

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        if agg in ("sum", "mean"):
            out_ref[...] = jnp.zeros_like(out_ref)
        elif agg == "min":
            out_ref[...] = jnp.full(out_ref.shape, jnp.inf, out_ref.dtype)
        elif agg == "max":
            out_ref[...] = jnp.full(out_ref.shape, -jnp.inf, out_ref.dtype)
        else:                                   # Welford mean / M2
            scratch[1][...] = jnp.zeros_like(scratch[1])
            scratch[2][...] = jnp.zeros_like(scratch[2])

    # (NB, EB) edge->node assignment for this tile pair; padding edges
    # carry dst == -1 and match no node row.
    node_ids = i * nb + jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    onehot = dst_ref[...] == node_ids
    msg = msg_ref[...].astype(jnp.float32)

    if agg in ("sum", "mean"):
        # scatter-add as a matmul: the MXU does the routing
        onef = onehot.astype(jnp.float32)
        out_ref[...] += jnp.dot(onef, msg,
                                preferred_element_type=jnp.float32)
        cnt_ref[...] += jnp.sum(onef, axis=1, keepdims=True)
    elif agg in ("min", "max"):
        # vectorized masked scatter (same shape as the fused kernel's):
        # unassigned (node, edge) pairs contribute the neutral element,
        # so one (NB, EB, F) where + edge-axis reduce replaces the
        # per-edge serial fori_loop
        neutral = jnp.inf if agg == "min" else -jnp.inf
        masked = jnp.where(onehot[:, :, None], msg[None], neutral)
        blk = masked.min(axis=1) if agg == "min" else masked.max(axis=1)
        out_ref[...] = jnp.minimum(out_ref[...], blk) if agg == "min" \
            else jnp.maximum(out_ref[...], blk)
        cnt_ref[...] += jnp.sum(onehot.astype(jnp.float32), axis=1,
                                keepdims=True)
    else:
        # Welford single-pass (paper §V-B): O(1) state per node row
        mean_ref, m2_ref = scratch[1], scratch[2]

        def body(e, state):
            mean, m2, cnt = state
            sel = jax.lax.dynamic_slice(onehot, (0, e), (nb, 1))
            row = jax.lax.dynamic_slice(msg, (e, 0), (1, f))
            cnt_new = cnt + sel.astype(jnp.float32)
            safe = jnp.maximum(cnt_new, 1.0)
            delta = row - mean
            mean_new = mean + jnp.where(sel, delta / safe, 0.0)
            m2_new = m2 + jnp.where(sel, delta * (row - mean_new), 0.0)
            return mean_new, m2_new, cnt_new
        mean, m2, cnt = jax.lax.fori_loop(
            0, eb, body, (mean_ref[...], m2_ref[...], cnt_ref[...]))
        mean_ref[...] = mean
        m2_ref[...] = m2
        cnt_ref[...] = cnt

    @pl.when(j == edge_steps - 1)
    def _finalize():
        if agg == "mean":
            out_ref[...] = out_ref[...] / jnp.maximum(cnt_ref[...], 1.0)
        elif agg in ("min", "max"):
            o = out_ref[...]
            out_ref[...] = jnp.where(jnp.isfinite(o), o, 0.0)
        elif agg in ("var", "std"):
            var = scratch[2][...] / jnp.maximum(cnt_ref[...], 1.0)
            var = jnp.maximum(var, 1e-12)   # clamp: sqrt'(0)=inf -> NaNs
            out_ref[...] = jnp.sqrt(var) if agg == "std" else var


def segment_aggregate_pallas(messages, seg_ids, num_segments: int, *,
                             agg: str = "sum", edge_block: int = 128,
                             node_block: int = 128,
                             interpret: bool = True):
    """messages: (E, F) in fp32, bf16, or int8 — tiles stream at the
    storage width, accumulation is fp32; seg_ids: (E,) int32 destination
    segment per edge, -1 (or any id outside [0, num_segments)) on
    padding. Returns (num_segments, F) float32 aggregates; empty
    segments zero-fill (the var/std clamp floor counts as zero at fp32
    tolerance).
    """
    assert agg in AGGS, agg
    e, f = messages.shape
    eb = min(edge_block, e)
    nb = min(node_block, num_segments)
    e_pad = (-e) % eb
    n_pad = (-num_segments) % nb
    seg_ids = seg_ids.astype(jnp.int32)
    # out-of-range ids (packed-batch overflow bucket == num_segments, or
    # -1 padding) are normalized to -1 so they match no node row
    seg_ids = jnp.where((seg_ids >= 0) & (seg_ids < num_segments),
                        seg_ids, -1)
    if e_pad:
        messages = jnp.pad(messages, ((0, e_pad), (0, 0)))
        seg_ids = jnp.pad(seg_ids, (0, e_pad), constant_values=-1)
    dst = seg_ids.reshape(1, e + e_pad)
    grid = ((num_segments + n_pad) // nb, (e + e_pad) // eb)
    scratch = [pltpu.VMEM((nb, 1), jnp.float32)]
    if agg in ("var", "std"):
        scratch += [pltpu.VMEM((nb, f), jnp.float32),
                    pltpu.VMEM((nb, f), jnp.float32)]
    out = pl.pallas_call(
        functools.partial(_seg_kernel, agg=agg, edge_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb, f), lambda i, j: (j, 0)),
            pl.BlockSpec((1, eb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((nb, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments + n_pad, f),
                                       jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(messages, dst)
    return out[:num_segments]


# ----------------------------------------------------------- segment v2 --
def _seg_v2_kernel(dst_ref, msg_hbm, out_ref, sbuf, sems, cnt_ref,
                   mean_ref, m2_ref, *, agg: str, edge_steps: int,
                   eb: int):
    """One grid step folds one message tile into the resident table.

    dst_ref is the *whole* id stream in SMEM (scalar prefetch); msg_hbm
    stays in HBM (memory_space=ANY) and is copied one edge block ahead
    of compute through the two-slot region of ``sbuf`` (a (2*EB, F)
    VMEM scratch at the message storage width) — the double-buffered
    HBM->VMEM edge pipeline. out_ref and the Welford mean/M2 scratch are
    whole-table VMEM residents, so the edge stream is swept once."""
    j = pl.program_id(0)

    def dma(slot, step):
        return pltpu.make_async_copy(
            msg_hbm.at[pl.ds(step * eb, eb), :],
            sbuf.at[pl.ds(slot * eb, eb), :], sems.at[slot])

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        if agg == "min":
            out_ref[...] = jnp.full(out_ref.shape, jnp.inf, out_ref.dtype)
        elif agg == "max":
            out_ref[...] = jnp.full(out_ref.shape, -jnp.inf,
                                    out_ref.dtype)
        else:
            out_ref[...] = jnp.zeros_like(out_ref)
        if agg in ("var", "std"):
            mean_ref[...] = jnp.zeros_like(mean_ref)
            m2_ref[...] = jnp.zeros_like(m2_ref)
        dma(0, 0).start()

    slot = jax.lax.rem(j, 2)

    @pl.when(j + 1 < edge_steps)
    def _prefetch_next():
        dma(1 - slot, j + 1).start()

    dma(slot, j).wait()

    base = j * eb

    def body(e, _):
        d = dst_ref[base + e]
        dl = jnp.maximum(d, 0)
        ok = d >= 0
        row = sbuf[pl.ds(slot * eb + e, 1), :].astype(jnp.float32)
        if agg in ("sum", "mean"):
            cur = out_ref[pl.ds(dl, 1), :]
            out_ref[pl.ds(dl, 1), :] = \
                jnp.where(ok, cur + row, cur)
        elif agg in ("min", "max"):
            cur = out_ref[pl.ds(dl, 1), :]
            upd = jnp.minimum(cur, row) if agg == "min" \
                else jnp.maximum(cur, row)
            out_ref[pl.ds(dl, 1), :] = jnp.where(ok, upd, cur)
        else:                               # Welford mean / M2
            c = cnt_ref[pl.ds(dl, 1), :]
            c_new = c + jnp.where(ok, 1.0, 0.0)
            mean = mean_ref[pl.ds(dl, 1), :]
            delta = row - mean
            mean_new = mean + jnp.where(
                ok, delta / jnp.maximum(c_new, 1.0), 0.0)
            m2 = m2_ref[pl.ds(dl, 1), :]
            mean_ref[pl.ds(dl, 1), :] = mean_new
            m2_ref[pl.ds(dl, 1), :] = \
                m2 + jnp.where(ok, delta * (row - mean_new), 0.0)
            cnt_ref[pl.ds(dl, 1), :] = c_new
        if agg in ("mean", "min", "max"):
            c = cnt_ref[pl.ds(dl, 1), :]
            cnt_ref[pl.ds(dl, 1), :] = c + jnp.where(ok, 1.0, 0.0)
        return 0

    jax.lax.fori_loop(0, eb, body, 0)

    @pl.when(j == edge_steps - 1)
    def _finalize():
        if agg == "mean":
            out_ref[...] = out_ref[...] / jnp.maximum(cnt_ref[...], 1.0)
        elif agg in ("min", "max"):
            o = out_ref[...]
            out_ref[...] = jnp.where(jnp.isfinite(o), o, 0.0)
        elif agg in ("var", "std"):
            var = m2_ref[...] / jnp.maximum(cnt_ref[...], 1.0)
            var = jnp.maximum(var, 1e-12)   # clamp: sqrt'(0)=inf -> NaNs
            out_ref[...] = jnp.sqrt(var) if agg == "std" else var


def segment_aggregate_v2_pallas(messages, seg_ids, num_segments: int, *,
                                agg: str = "sum", edge_block: int = 128,
                                node_block: int = 128,
                                interpret: bool = True):
    """One-hot-free segment aggregation (``gather_mode="dma"``, the
    default) — same contract as ``segment_aggregate_pallas`` (messages
    (E, F) at fp32/bf16/int8 storage width, fp32 accumulation, seg_ids
    (E,) with -1/out-of-range = padding, (num_segments, F) float32 out,
    empty segments zero-fill) — but a different machine: the dst stream
    rides in SMEM via scalar prefetch, message tiles are double-buffered
    HBM->VMEM by explicit async copies, and the whole accumulator table
    (plus the Welford mean/M2 pair for var/std) is VMEM-resident, so the
    edge stream is swept exactly once (``node_block`` is accepted for
    knob compatibility and ignored).

    Grid: (edge_tiles,). Scratch: two-slot (2*EB, F) message buffer at
    storage width + a DMA semaphore pair + the (num_segments, 1) count
    column + (num_segments, F) Welford mean/M2 for var/std.
    """
    assert agg in AGGS, agg
    del node_block                       # v2 keeps the whole table
    e, f = messages.shape
    if e == 0 or num_segments == 0:
        return jnp.zeros((num_segments, f), jnp.float32)
    seg_ids = seg_ids.astype(jnp.int32)
    seg_ids = jnp.where((seg_ids >= 0) & (seg_ids < num_segments),
                        seg_ids, -1)
    eb = min(edge_block, e)
    e_pad = (-e) % eb
    if e_pad:
        messages = jnp.pad(messages, ((0, e_pad), (0, 0)))
        seg_ids = jnp.pad(seg_ids, (0, e_pad), constant_values=-1)
    steps = (e + e_pad) // eb
    welford = agg in ("var", "std")
    track_count = agg != "sum"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # messages stay HBM
        ],
        out_specs=pl.BlockSpec((num_segments, f),
                               lambda j, d_r: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2 * eb, f), messages.dtype),  # two-slot buffer
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((num_segments if track_count else 8, 1),
                       jnp.float32),
            pltpu.VMEM((num_segments if welford else 8, f), jnp.float32),
            pltpu.VMEM((num_segments if welford else 8, f), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_seg_v2_kernel, agg=agg, edge_steps=steps,
                          eb=eb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, f), jnp.float32),
        interpret=interpret,
    )(seg_ids, messages)
    return out
