"""jit'd public wrapper for segment_aggregate."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_aggregate.kernel import (
    segment_aggregate_pallas, segment_aggregate_v2_pallas)
from repro.kernels.segment_aggregate.ref import segment_aggregate_ref

GATHER_MODES = ("onehot", "dma")


@partial(jax.jit, static_argnames=("num_segments", "agg", "edge_block",
                                   "node_block", "use_pallas", "interpret",
                                   "gather_mode"))
def segment_aggregate(messages, seg_ids, valid=None, *, num_segments: int,
                      agg: str = "sum", edge_block: int = 128,
                      node_block: int = 128, use_pallas: bool = True,
                      interpret: bool = True, gather_mode: str = "dma"):
    """Aggregate packed COO edge messages per destination segment.

    messages (E, F) — fp32, bf16, or int8; tiles stream at the storage
    width, accumulation is fp32 (callers dequantize int8 outputs, see
    ``core.aggregations.segment_aggregate(precision=...)``); seg_ids
    (E,) int32 destination ids, with padding marked by -1, any id >=
    num_segments (the packed-batch overflow bucket), or
    ``valid == False``. Returns (num_segments, F) float32.

    gather_mode selects the kernel generation: "dma" (default) is the
    one-hot-free v2 schedule — scalar-prefetched dst stream,
    double-buffered message-tile DMA, whole-table VMEM accumulators
    (incl. the Welford mean/M2 pair), one sweep over the edge stream;
    "onehot" is the legacy (NB, EB) destination one-hot schedule kept
    for comparison and DSE featurization (docs/KERNELS.md).

    use_pallas=False falls back to the pure-jnp mirror oracle (ref.py) —
    a testing aid whose dense (N, E, F) min/max/var intermediates do not
    scale to production buffers. The production fallback under pjit is
    ``core.aggregations.segment_aggregate(backend="xla")``, which is also
    the process default; Pallas engages on single-device serving."""
    if gather_mode not in GATHER_MODES:
        raise ValueError(f"unknown gather_mode {gather_mode!r}; expected "
                         f"one of {GATHER_MODES}")
    seg_ids = seg_ids.astype(jnp.int32)
    if valid is not None:
        seg_ids = jnp.where(valid, seg_ids, -1)
    if use_pallas:
        kern = segment_aggregate_v2_pallas if gather_mode == "dma" \
            else segment_aggregate_pallas
        return kern(messages, seg_ids, num_segments, agg=agg,
                    edge_block=edge_block, node_block=node_block,
                    interpret=interpret)
    return segment_aggregate_ref(messages, seg_ids, num_segments, agg=agg)
