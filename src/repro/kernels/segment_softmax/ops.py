"""jit'd public wrapper for segment_softmax."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_softmax.kernel import segment_softmax_pallas
from repro.kernels.segment_softmax.ref import segment_softmax_ref


@partial(jax.jit, static_argnames=("num_segments", "edge_block",
                                   "use_pallas", "interpret"))
def segment_softmax(logits, seg_ids, valid=None, *, num_segments: int,
                    edge_block: int = 128, use_pallas: bool = True,
                    interpret: bool = True):
    """Normalize packed per-edge logits within each destination segment.

    logits (E,) float — any magnitude; the online-softmax state machine
    subtracts the running per-segment max before every exp, so +-1e4
    logits stay finite. seg_ids (E,) int32 destination ids, with padding
    marked by -1, any id >= num_segments, or ``valid == False``. A -inf
    logit on a valid edge is a masked attention slot. Returns (E,)
    float32 weights: each non-empty segment's rows sum to 1; padding
    edges, masked slots, and members of all-masked segments get exactly
    0 — never NaN/Inf.

    use_pallas=False falls back to the dense one-hot oracle (ref.py) —
    a testing aid with an O(num_segments * E) intermediate. The
    production fallback under pjit is
    ``core.aggregations.segment_softmax(backend="xla")``."""
    seg_ids = seg_ids.astype(jnp.int32)
    if valid is not None:
        seg_ids = jnp.where(valid, seg_ids, -1)
    if use_pallas:
        return segment_softmax_pallas(logits, seg_ids, num_segments,
                                      edge_block=edge_block,
                                      interpret=interpret)
    return segment_softmax_ref(logits, seg_ids, num_segments)
