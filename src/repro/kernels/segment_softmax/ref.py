"""Dense one-hot oracle for the segment-softmax kernel.

Same contract as ``kernel.segment_softmax_pallas`` — (E,) logits +
(E,) seg ids -> (E,) weights summing to 1 per non-empty segment, 0 on
padding / -inf-masked / all-masked rows — computed the obviously-correct
way: materialize the (num_segments, E) membership one-hot, subtract the
per-segment masked max, exponentiate, normalize. O(S * E) memory, fine
at test sizes; the equivalence tests pin the kernel against this.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30
TINY = 1e-30


def segment_softmax_ref(logits, seg_ids, num_segments: int):
    e = logits.shape[0]
    if e == 0 or num_segments == 0:
        return jnp.zeros((e,), jnp.float32)
    seg = jnp.asarray(seg_ids, jnp.int32)
    seg = jnp.where((seg >= 0) & (seg < num_segments), seg, -1)
    z = logits.astype(jnp.float32)
    onehot = seg[None, :] == jnp.arange(num_segments)[:, None]  # (S, E)
    masked = jnp.where(onehot, z[None, :], -jnp.inf)
    m = masked.max(axis=1)                       # -inf on empty segments
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(onehot, jnp.exp(jnp.where(onehot, z[None, :], NEG_INF)
                                  - m_safe[:, None]), 0.0)
    denom = jnp.maximum(p.sum(axis=1, keepdims=True), TINY)
    # segments are disjoint: summing the one-hot rows recovers per-edge
    return (p / denom).sum(axis=0)
