"""Pallas TPU kernel: numerically stable per-segment softmax statistics
over the packed COO edge stream.

Attention convs (GAT) normalize per-edge logits within each
*destination* segment: ``alpha_e = exp(z_e - m_dst) / sum_e' exp(...)``.
The reduction shape is the same as ``segment_aggregate`` — one pass over
the edge stream folding into a VMEM-resident per-segment table — but the
state machine is the online softmax of ``kernels/flash_attention``: a
running max ``m`` and a running exp-sum ``l`` corrected by
``exp(m_prev - m_new)`` whenever the max moves, so ``exp`` never sees a
positive argument regardless of logit magnitude (the +-1e4 stability
contract, docs/KERNELS.md).

The kernel produces the per-segment (max, denominator) tables; the
per-edge normalization ``exp(z - m[seg]) / max(l[seg], tiny)`` is a
cheap elementwise gather done by the caller (``segment_softmax_pallas``)
— per-edge *outputs* would otherwise force a second DMA sweep for what
XLA already fuses.

Masking: seg_ids carry -1 (or out-of-range ids) on padding edges; a
-inf logit on a *valid* edge is a masked attention slot — it contributes
``exp(-inf) == 0`` to the denominator and gets weight 0 without ever
producing a NaN (the running max is clamped at ``NEG_INF = -1e30``, so
the kernel never evaluates ``-inf - (-inf)``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30     # finite "empty" max: keeps -inf logits NaN-free
TINY = 1e-30        # denominator floor (empty segments divide by this)


def _softmax_stats_kernel(seg_ref, logit_hbm, m_ref, l_ref, sbuf, sems, *,
                          edge_steps: int, eb: int):
    """One grid step folds one logit tile into the resident (m, l)
    tables. Same machine as ``segment_aggregate._seg_v2_kernel``: the
    whole seg-id stream rides SMEM via scalar prefetch, logit tiles are
    double-buffered HBM->VMEM, and both per-segment tables stay
    VMEM-resident across the single edge sweep."""
    j = pl.program_id(0)

    def dma(slot, step):
        return pltpu.make_async_copy(
            logit_hbm.at[pl.ds(step * eb, eb), :],
            sbuf.at[pl.ds(slot * eb, eb), :], sems.at[slot])

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, m_ref.dtype)
        l_ref[...] = jnp.zeros_like(l_ref)
        dma(0, 0).start()

    slot = jax.lax.rem(j, 2)

    @pl.when(j + 1 < edge_steps)
    def _prefetch_next():
        dma(1 - slot, j + 1).start()

    dma(slot, j).wait()

    base = j * eb

    def body(e, _):
        d = seg_ref[base + e]
        dl = jnp.maximum(d, 0)
        ok = d >= 0
        z = sbuf[pl.ds(slot * eb + e, 1), :].astype(jnp.float32)
        m_prev = m_ref[pl.ds(dl, 1), :]
        # the running max never drops below NEG_INF, so a -inf logit
        # leaves it unchanged and exp(m_prev - m_new) stays exp(0) = 1
        m_new = jnp.maximum(m_prev, z)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(z - m_new)
        l_prev = l_ref[pl.ds(dl, 1), :]
        m_ref[pl.ds(dl, 1), :] = jnp.where(ok, m_new, m_prev)
        l_ref[pl.ds(dl, 1), :] = jnp.where(ok, l_prev * corr + p, l_prev)
        return 0

    jax.lax.fori_loop(0, eb, body, 0)


def segment_softmax_stats_pallas(logits, seg_ids, num_segments: int, *,
                                 edge_block: int = 128,
                                 interpret: bool = True):
    """Per-segment online-softmax statistics over a packed edge stream.

    logits: (E,) float; seg_ids: (E,) int32 with -1 / out-of-range =
    padding. Returns ``(m, l)``: (num_segments,) float32 running max
    (NEG_INF for empty segments) and exp-sum denominator (0 for empty
    segments). Grid: (edge_tiles,); scratch: two-slot (2*EB, 1) logit
    buffer + a DMA semaphore pair; both output tables VMEM-resident."""
    e = logits.shape[0]
    if e == 0 or num_segments == 0:
        return (jnp.full((num_segments,), NEG_INF, jnp.float32),
                jnp.zeros((num_segments,), jnp.float32))
    seg_ids = seg_ids.astype(jnp.int32)
    seg_ids = jnp.where((seg_ids >= 0) & (seg_ids < num_segments),
                        seg_ids, -1)
    z = logits.astype(jnp.float32).reshape(e, 1)
    eb = min(edge_block, e)
    e_pad = (-e) % eb
    if e_pad:
        z = jnp.pad(z, ((0, e_pad), (0, 0)))
        seg_ids = jnp.pad(seg_ids, (0, e_pad), constant_values=-1)
    steps = (e + e_pad) // eb
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),      # logits stay HBM
        ],
        out_specs=[
            pl.BlockSpec((num_segments, 1), lambda j, s_r: (0, 0)),
            pl.BlockSpec((num_segments, 1), lambda j, s_r: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2 * eb, 1), jnp.float32),      # two-slot buffer
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    m, l = pl.pallas_call(
        functools.partial(_softmax_stats_kernel, edge_steps=steps, eb=eb),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_segments, 1), jnp.float32),
            jax.ShapeDtypeStruct((num_segments, 1), jnp.float32),
        ],
        interpret=interpret,
    )(seg_ids, z)
    return m[:, 0], l[:, 0]


def segment_softmax_pallas(logits, seg_ids, num_segments: int, *,
                           edge_block: int = 128,
                           interpret: bool = True):
    """Per-edge softmax weights normalized within each segment.

    logits: (E,); seg_ids: (E,) with -1 / out-of-range = padding.
    Returns (E,) float32: rows of each non-empty segment sum to 1;
    padding edges, -inf-masked logits, and members of all-masked
    segments get exactly 0 — never NaN/Inf."""
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    seg_ids = jnp.where((seg_ids >= 0) & (seg_ids < num_segments),
                        seg_ids, -1)
    m, l = segment_softmax_stats_pallas(
        logits, seg_ids, num_segments, edge_block=edge_block,
        interpret=interpret)
    ok = seg_ids >= 0
    sl = jnp.maximum(seg_ids, 0)
    z = logits.astype(jnp.float32)
    # padding logits can exceed their (clamped) segment max, so exp may
    # overflow to +inf on lanes the where() discards — mask first
    p = jnp.where(ok, jnp.exp(jnp.where(ok, z, NEG_INF)
                              - jnp.take(m, sl)), 0.0)
    return p / jnp.maximum(jnp.take(l, sl), TINY)
