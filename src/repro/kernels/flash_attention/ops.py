"""jit'd wrapper for flash_attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, use_pallas: bool = True,
                    interpret: bool = True):
    """q/k/v: (B, H, S, D) or (BH, S, D)."""
    squeeze = q.ndim == 4
    if squeeze:
        b, h, s, d = q.shape
        rs = lambda t: t.reshape(b * h, *t.shape[2:])
        q, k, v = rs(q), rs(k), rs(v)
    if use_pallas:
        # pad seq dims to tile multiples
        sq, skv = q.shape[1], k.shape[1]
        bq, bk = min(block_q, sq), min(block_k, skv)
        pq, pk = (-sq) % bq, (-skv) % bk
        if pq:
            q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
        if pk:
            k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
        out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                     block_k=bk, interpret=interpret)
        out = out[:, :sq]
    else:
        out = attention_ref(q, k, v, causal=causal)
    if squeeze:
        out = out.reshape(b, h, s, -1)
    return out
