"""Pure-jnp oracle for flash_attention."""
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    bh, sq, d = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    if causal:
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
