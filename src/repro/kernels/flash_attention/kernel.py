"""Pallas TPU kernel: blocked online-softmax attention (forward).

The LM-substrate hot spot (beyond the paper): causal flash attention with
(block_q x block_k) tiles, fp32 running max / denominator / accumulator in
VMEM scratch. Grid: (batch*heads, Sq/bq, Skv/bk) with the KV axis as the
sequential (innermost) dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  kv_steps: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
    k = k_ref[0].astype(jnp.float32)                # (bk, d)
    v = v_ref[0].astype(jnp.float32)                # (bk, dv)
    s = q @ k.T                                     # (bq, bk)
    if causal:
        qi = pl.program_id(1)
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], k.shape[0]), 0)
        k_pos = kv_i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], k.shape[0]), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_new = acc_prev * corr + p @ v
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(kv_i == kv_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (BH, Sq, D); k, v: (BH, Skv, D) -> (BH, Sq, D)."""
    bh, sq, d = q.shape
    skv, dv = k.shape[1], v.shape[-1]
    bq, bk = min(block_q, sq), min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, "pad seq to tile multiples"
    kv_steps = skv // bk
    scale = d ** -0.5
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=bq, block_k=bk, kv_steps=kv_steps),
        grid=(bh, sq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
