"""Pure-jnp oracle for tiled_linear."""
import jax.numpy as jnp


def tiled_matmul_ref(x, w):
    return jnp.dot(x.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)
