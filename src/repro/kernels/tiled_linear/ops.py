"""jit'd wrapper + parallelism-factor -> tile-size mapping."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.tiled_linear.kernel import tiled_matmul_pallas
from repro.kernels.tiled_linear.ref import tiled_matmul_ref

LANE = 128  # MXU systolic dimension


def blocks_from_parallelism(p_in: int, p_out: int) -> tuple:
    """GNNBuilder parallelism factors -> MXU-aligned tile sizes.

    p_in scales the reduction tile (BLOCK_SIZE_IN), p_out the output tile
    (BLOCK_SIZE_OUT); both clamp to hardware-aligned multiples of 128."""
    block_k = max(LANE, min(p_in, 8) * LANE // 2)
    block_n = max(LANE, min(p_out, 8) * LANE // 2)
    return block_k, block_n


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "use_pallas", "interpret"))
def tiled_matmul(x, w, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128, use_pallas: bool = True,
                 interpret: bool = True):
    if use_pallas:
        return tiled_matmul_pallas(x, w, block_m=block_m, block_n=block_n,
                                   block_k=block_k, interpret=interpret)
    return tiled_matmul_ref(x, w)
