"""Pallas TPU kernel: tiled matmul (paper §V-B "Linear Layer").

GNNBuilder parallelizes linear layers with BLOCK_SIZE_IN/BLOCK_SIZE_OUT
partition factors controlling MAC parallelism; the TPU analogue is the
(block_m, block_k, block_n) BlockSpec tiling feeding the 128x128 MXU.
Parallelism factors p_in/p_out map to block_k/block_n multiples of the
hardware lane width (see ops.blocks_from_parallelism).

Grid: (M/bm, N/bn, K/bk) with a VMEM fp32 accumulator; K is the reduction
(sequential) dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def tiled_matmul_pallas(x, w, *, block_m: int = 128, block_n: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """x: (M, K) @ w: (K, N) -> (M, N), fp32 accumulation in VMEM."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    mm, nn, kk = m + pm, n + pn, k + pk
    k_steps = kk // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mm // bm, nn // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk_: (i, kk_)),
            pl.BlockSpec((bk, bn), lambda i, j, kk_: (kk_, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:m, :n]
