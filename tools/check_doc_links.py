"""Docs link check (the CI docs-job step).

Three invariants keep the documentation front door honest:

1. every relative markdown link in README.md, docs/*.md, and the
   root-level design docs resolves to an existing file;
2. every docs/*.md is reachable from README.md (no orphan pages);
3. every docs/*.md links back to the README (the pages are a tree,
   not a pile).

External (http/https/mailto) links and intra-page anchors are out of
scope — this guards the relative-path graph only, which is what rots
when files move.

  python tools/check_doc_links.py [repo_root]
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) — excluding images handled identically, fine to include
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def md_links(path: str) -> list:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    out = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        out.append(target.split("#", 1)[0])
    return out


def main(root: str) -> int:
    readme = os.path.join(root, "README.md")
    docs_dir = os.path.join(root, "docs")
    pages = [readme] + sorted(
        os.path.join(root, n) for n in os.listdir(root)
        if n.endswith(".md") and n != "README.md") + sorted(
        os.path.join(docs_dir, n) for n in os.listdir(docs_dir)
        if n.endswith(".md"))
    errors = []

    # 1. every relative link resolves
    for page in pages:
        for target in md_links(page):
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(page), target))
            if not os.path.exists(resolved):
                errors.append(f"{os.path.relpath(page, root)}: dead link "
                              f"-> {target}")

    # 2. every docs/*.md is referenced from README.md
    readme_targets = {os.path.normpath(os.path.join(root, t))
                      for t in md_links(readme)}
    for name in sorted(os.listdir(docs_dir)):
        if not name.endswith(".md"):
            continue
        page = os.path.normpath(os.path.join(docs_dir, name))
        if page not in readme_targets:
            errors.append(f"docs/{name}: not linked from README.md")

        # 3. ... and links back to the README
        back = {os.path.normpath(os.path.join(docs_dir, t))
                for t in md_links(page)}
        if os.path.normpath(readme) not in back:
            errors.append(f"docs/{name}: no link back to README.md")

    for e in errors:
        print(f"::error::{e}")
    if not errors:
        n_links = sum(len(md_links(p)) for p in pages)
        print(f"doc link check OK: {len(pages)} pages, "
              f"{n_links} relative links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else
                  os.path.join(os.path.dirname(__file__), "..")))
