"""Checkpoint manager: atomic save/restore, keep-N GC, async, and the
elastic 8->4 device re-shard path (subprocess with fake devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": {"w": jax.random.normal(k, (8, 4))},
            "b": [jnp.arange(3), jnp.float32(7.5)],
            "step": jnp.int32(11)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(5, tree)
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 5
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        tree, restored)


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(7, _tree())
    mgr.wait()
    assert mgr.latest_step() == 7
    restored, meta = mgr.restore(_tree())
    assert meta["step"] == 7


def test_restore_missing_key_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.ones(3)})
    with pytest.raises(KeyError):
        mgr.restore({"a": jnp.ones(3), "extra": jnp.ones(2)})


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names)


ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.elastic import replan, plan_mesh_shape

    d = jax.devices()
    mesh8 = Mesh(np.array(d).reshape(4, 2), ("data", "model"))
    sh8 = NamedSharding(mesh8, P("data", "model"))
    x = jax.device_put(jnp.arange(32.0).reshape(8, 4), sh8)
    mgr = CheckpointManager("{dir}", keep=1)
    mgr.save(3, {{"x": x}})

    # "lose" 4 devices -> replan on survivors, restore resharded
    survivors = d[:4]
    mesh4 = replan(survivors, model_pref=2)
    assert mesh4.devices.shape == (2, 2), mesh4.devices.shape
    sh4 = NamedSharding(mesh4, P("data", "model"))
    restored, meta = mgr.restore({{"x": x}}, shardings={{"x": sh4}})
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.arange(32.0).reshape(8, 4))
    assert len(restored["x"].sharding.device_set) == 4
    print("ELASTIC_OK")
""")


def test_elastic_reshard_8_to_4(tmp_path):
    script = ELASTIC_SCRIPT.format(dir=str(tmp_path))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
