"""Backend equivalence for the packed-COO segment aggregation: the Pallas
edge-block kernel (interpret mode on CPU) must match the XLA
jax.ops.segment_* path for all six aggregations, including the Welford
var/std edge cases — empty segments, all-padding edge blocks, and
single-edge segments."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import aggregations as A
from repro.data import pipeline as P
from repro.kernels.segment_aggregate.ops import (
    segment_aggregate as pallas_segment_aggregate)
from repro.kernels.segment_aggregate.ref import segment_aggregate_ref

RNG = np.random.default_rng(17)
ATOL = 1e-5


def _check(agg, msgs, seg, n, valid=None, edge_block=64, node_block=32):
    got = pallas_segment_aggregate(
        jnp.asarray(msgs), jnp.asarray(seg),
        None if valid is None else jnp.asarray(valid),
        num_segments=n, agg=agg, edge_block=edge_block,
        node_block=node_block)
    want = A.segment_aggregate(
        agg, jnp.asarray(msgs), jnp.asarray(seg), n,
        None if valid is None else jnp.asarray(valid), backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=ATOL, rtol=1e-4)
    # ref.py mirrors kernel.py (the kernel-dir contract)
    seg_eff = np.where(valid, seg, -1) if valid is not None else seg
    ref = segment_aggregate_ref(jnp.asarray(msgs), jnp.asarray(seg_eff),
                                n, agg=agg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("agg", A.AGGREGATIONS)
@pytest.mark.parametrize("e,f,n,eb,nb", [
    (200, 16, 40, 64, 32),
    (77, 9, 33, 32, 32),        # ragged: padding in both axes
    (128, 8, 8, 128, 128),      # single tile pair
])
def test_pallas_matches_xla(agg, e, f, n, eb, nb):
    msgs = RNG.standard_normal((e, f)).astype(np.float32)
    # ids cover the overflow-bucket convention (seg == n on padding)
    seg = RNG.integers(0, n + 1, e).astype(np.int32)
    valid = RNG.random(e) < 0.8
    _check(agg, msgs, seg, n, valid, eb, nb)


@pytest.mark.parametrize("agg", A.AGGREGATIONS)
def test_empty_segments_zero_fill(agg):
    """Segments with no edges zero-fill on both backends (var/std clamp
    to 1e-12 -> zero at fp32 tolerance)."""
    msgs = RNG.standard_normal((32, 5)).astype(np.float32)
    seg = np.full((32,), 3, np.int32)       # all edges land on segment 3
    _check(agg, msgs, seg, 8)
    got = np.asarray(pallas_segment_aggregate(
        jnp.asarray(msgs), jnp.asarray(seg), num_segments=8, agg=agg,
        edge_block=32, node_block=8))
    mask = np.ones(8, bool)
    mask[3] = False
    np.testing.assert_allclose(got[mask], 0.0, atol=ATOL)


@pytest.mark.parametrize("agg", A.AGGREGATIONS)
def test_all_padding_edge_block(agg):
    """A whole edge block of padding (-1 / overflow ids / invalid) must
    not perturb the accumulators of other blocks."""
    eb = 32
    msgs = RNG.standard_normal((3 * eb, 4)).astype(np.float32)
    seg = RNG.integers(0, 6, 3 * eb).astype(np.int32)
    seg[eb:2 * eb] = -1                       # middle block: all padding
    valid = np.ones(3 * eb, bool)
    valid[eb:2 * eb] = False
    _check(agg, msgs, seg, 6, valid, edge_block=eb, node_block=6)


@pytest.mark.parametrize("agg", A.AGGREGATIONS)
def test_single_edge_segments(agg):
    """One edge per segment: Welford count==1 path (var/std clamp floor,
    mean == the message itself)."""
    n = 12
    msgs = RNG.standard_normal((n, 7)).astype(np.float32)
    seg = np.arange(n, dtype=np.int32)
    _check(agg, msgs, seg, n, edge_block=8, node_block=4)
    got = np.asarray(pallas_segment_aggregate(
        jnp.asarray(msgs), jnp.asarray(seg), num_segments=n, agg=agg,
        edge_block=8, node_block=4))
    if agg in ("sum", "mean", "min", "max"):
        np.testing.assert_allclose(got, msgs, atol=ATOL, rtol=1e-5)
    else:                                     # var=1e-12 clamp, std=1e-6
        np.testing.assert_allclose(got, 0.0, atol=ATOL)


@pytest.mark.parametrize("agg", A.AGGREGATIONS)
def test_packed_graphbatch_edge_stream(agg):
    """The real consumer layout: dst ids from a packed GraphBatch's edge
    buffer, padding edges marked by src == -1."""
    ds = P.GraphDataConfig(avg_nodes=10, max_nodes=64, max_edges=64,
                           node_feat_dim=6, edge_feat_dim=2, seed=9)
    graphs = [P.make_graph(ds, i) for i in range(5)]
    batch, _ = P.pack_graphs(graphs, 128, 256, 8)
    msgs = RNG.standard_normal((256, 6)).astype(np.float32)
    dst = batch["edge_index"][:, 1]
    valid = batch["edge_index"][:, 0] >= 0
    _check(agg, msgs, dst, 128, valid, edge_block=64, node_block=64)


def test_backend_switch_and_default():
    """core.aggregations dispatches by backend=; set_default_backend /
    backend_scope flip the process default and restore it."""
    msgs = jnp.asarray(RNG.standard_normal((40, 3)), jnp.float32)
    seg = jnp.asarray(RNG.integers(0, 10, 40), jnp.int32)
    want = A.segment_aggregate("mean", msgs, seg, 10, backend="xla")
    got = A.segment_aggregate("mean", msgs, seg, 10, backend="pallas",
                              edge_block=32, node_block=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=ATOL)
    assert A.default_backend() == "xla"
    with A.backend_scope("pallas", edge_block=16, node_block=8):
        assert A.default_backend() == "pallas"
        inner = A.segment_aggregate("sum", msgs, seg, 10)
        np.testing.assert_allclose(
            np.asarray(inner),
            np.asarray(A.segment_aggregate("sum", msgs, seg, 10,
                                           backend="xla")), atol=ATOL)
    assert A.default_backend() == "xla"
    with pytest.raises(ValueError):
        A.set_default_backend("cuda")
    with pytest.raises(ValueError):
        A.segment_aggregate("sum", msgs, seg, 10, backend="nope")


# ------------------------------------------- gather_mode="dma" tier ----
@pytest.mark.parametrize("agg", A.AGGREGATIONS)
def test_dma_gather_matches_onehot_and_ref(agg):
    """The one-hot-free DMA gather must match the legacy one-hot
    contraction and ref.py on a hostile id stream: pad (-1), overflow
    (n+1) and invalid rows mixed through every edge block."""
    e, f, n = 300, 24, 70
    msgs = RNG.standard_normal((e, f)).astype(np.float32)
    seg = RNG.integers(-1, n + 2, e).astype(np.int32)
    valid = RNG.random(e) > 0.1
    ref = np.asarray(segment_aggregate_ref(
        jnp.asarray(msgs),
        jnp.where(jnp.asarray(valid), jnp.asarray(seg), -1), n, agg=agg))
    for mode in ("onehot", "dma"):
        got = np.asarray(pallas_segment_aggregate(
            jnp.asarray(msgs), jnp.asarray(seg), jnp.asarray(valid),
            num_segments=n, agg=agg, edge_block=64, gather_mode=mode))
        np.testing.assert_allclose(got, ref, atol=2e-5, rtol=2e-5,
                                   err_msg=f"{agg}/{mode}")


@pytest.mark.parametrize("agg", ("sum", "var"))
def test_dma_gather_bf16_matches_onehot(agg):
    """Low-precision tiles ride the same DMA path: both gather
    generations accumulate identically on bf16 inputs."""
    e, f, n = 300, 24, 70
    msgs = jnp.asarray(RNG.standard_normal((e, f)), jnp.bfloat16)
    seg = jnp.asarray(RNG.integers(-1, n + 2, e), jnp.int32)
    a = pallas_segment_aggregate(msgs, seg, num_segments=n, agg=agg,
                                 gather_mode="onehot")
    b = pallas_segment_aggregate(msgs, seg, num_segments=n, agg=agg,
                                 gather_mode="dma")
    assert a.dtype == b.dtype
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_dma_gather_empty_and_short_streams():
    """Degenerate shapes: a zero-edge stream zero-fills every segment,
    and a stream shorter than one edge block still reduces exactly."""
    n, f = 70, 24
    out = pallas_segment_aggregate(
        jnp.zeros((0, f), jnp.float32), jnp.zeros((0,), jnp.int32),
        num_segments=n, agg="sum", gather_mode="dma")
    assert out.shape == (n, f)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=0.0)
    msgs = jnp.asarray(RNG.standard_normal((5, f)), jnp.float32)
    seg = jnp.asarray(RNG.integers(0, n, 5), jnp.int32)
    got = pallas_segment_aggregate(msgs, seg, num_segments=n, agg="mean",
                                   edge_block=128, gather_mode="dma")
    ref = segment_aggregate_ref(msgs, seg, n, agg="mean")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=ATOL)


def test_use_pallas_false_falls_back_to_ref():
    msgs = jnp.asarray(RNG.standard_normal((24, 4)), jnp.float32)
    seg = jnp.asarray(RNG.integers(0, 6, 24), jnp.int32)
    a = pallas_segment_aggregate(msgs, seg, num_segments=6, agg="var",
                                 use_pallas=False)
    b = pallas_segment_aggregate(msgs, seg, num_segments=6, agg="var",
                                 edge_block=8, node_block=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL)
