"""Fault-tolerant serving: deterministic fault-injection tests.

Everything runs on the injected ``VirtualClock`` with scripted or
seed-driven ``runtime.faults`` plans — closed-form retry/timeout/
quarantine timelines, dead-letter accounting, probe-back recovery, and
randomized exactly-once sweeps under >= 10% fault injection, all
bit-identical on every run with zero sleeps. The property test runs
twice: a seeded numpy sweep always, and a hypothesis-driven version
when hypothesis is installed (guarded import; the container image does
not ship it)."""
import dataclasses
import math

import numpy as np
import pytest

from repro.data import pipeline as P
from repro.runtime import scheduler as S
from repro.runtime.faults import FaultPlan, FaultSpec, FaultyExecutor

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(not HAS_HYPOTHESIS,
                                      reason="hypothesis not installed")

DS = P.GraphDataConfig(avg_nodes=8, avg_degree=2, node_feat_dim=5,
                       edge_feat_dim=3, max_nodes=64, max_edges=64, seed=3)


def lane(service: float = 0.2, with_outputs: bool = False):
    """A SimExecutor lane; ``with_outputs`` adds cheap zero outputs so
    corruption faults have an array to poison."""
    if not with_outputs:
        return S.SimExecutor(S.constant_service(service))
    return S.SimExecutor(
        S.constant_service(service),
        batch_fn=lambda b: np.zeros((len(b["graph_valid"]), 1),
                                    np.float32),
        fallback_fn=lambda g: np.zeros((1,), np.float32))


def sched_with(lanes, *, deadline: float = 0.0, timeout: float = math.inf,
               max_retries: int = 2, backoff: float = 0.0,
               backoff_cap: float = 0.5, quarantine_after: int = 2,
               cooldown: float = 0.3, validate: bool = False,
               clock=None) -> S.ContinuousScheduler:
    cfg = S.SchedulerConfig(
        1000, 1000, max_graphs=1,
        default_tier=S.SLOTier("standard", deadline, 1),
        launch_timeout_s=timeout, max_retries=max_retries,
        retry_backoff_s=backoff, retry_backoff_cap_s=backoff_cap,
        quarantine_after=quarantine_after, quarantine_cooldown_s=cooldown,
        quarantine_cooldown_cap_s=8 * cooldown if cooldown else 1.0,
        validate=validate)
    return S.ContinuousScheduler(cfg, lanes, clock=clock)


def faulty(inner, specs, clock=None) -> FaultyExecutor:
    return FaultyExecutor(inner, FaultPlan(specs), clock)


# ------------------------------------------------------------ fault plans --

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor", launch=0)
    with pytest.raises(ValueError, match="exactly one trigger"):
        FaultSpec("crash")
    with pytest.raises(ValueError, match="exactly one trigger"):
        FaultSpec("crash", launch=0, at_s=1.0)


def test_fault_plan_random_is_deterministic():
    rates = {"crash": 0.1, "hang": 0.05, "corrupt": 0.1}
    a = FaultPlan.random(seed=4, n_calls=200, rates=rates)
    b = FaultPlan.random(seed=4, n_calls=200, rates=rates)
    assert [(s.kind, s.launch) for s in a.specs] \
        == [(s.kind, s.launch) for s in b.specs]
    assert len(a.specs) > 0
    c = FaultPlan.random(seed=5, n_calls=200, rates=rates)
    assert [(s.kind, s.launch) for s in a.specs] \
        != [(s.kind, s.launch) for s in c.specs]
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.random(seed=0, n_calls=10, rates={"meteor": 0.5})


def test_faulty_executor_at_s_trigger_and_one_shot():
    clock = S.VirtualClock()
    ex = faulty(lane(0.1), [FaultSpec("slowdown", at_s=1.0, factor=3.0)],
                clock)
    g = P.make_graph(DS, 0)
    batch, _ = P.pack_graphs([g], 1000, 1000, 1)
    assert ex.run_batch(batch)[1] == pytest.approx(0.1)   # before at_s
    clock.advance_to(1.0)
    assert ex.run_batch(batch)[1] == pytest.approx(0.3)   # fires once
    assert ex.run_batch(batch)[1] == pytest.approx(0.1)   # consumed
    assert ex.injected == [(1, "slowdown")]
    assert ex.can_fallback == lane(0.1).can_fallback


# ------------------------------------------------- closed-form timelines --

def test_crash_retries_with_backoff_closed_form():
    """Crash at launch, retry after exactly the configured backoff on
    the (degraded but available) lane: latency = backoff + service."""
    lanes = [faulty(lane(0.2), [FaultSpec("crash", launch=0)])]
    sched = sched_with(lanes, backoff=0.1, quarantine_after=5)
    sched.submit(P.make_graph(DS, 0))
    sched.drain()
    (r,) = sched.responses
    assert r.status == S.SERVED_PACKED
    assert r.latency_s == pytest.approx(0.3)
    s = sched.summary()
    assert s["retries"] == 1 and s["failed_launches"] == 1
    assert s["lane_states"] == [S.LANE_HEALTHY]   # success cleared degraded
    (ev,) = [e for e in sched.events if e["kind"] == "launch_failed"]
    assert ev["error"] == S.FAIL_CRASH and ev["req_ids"] == [0]


def test_hang_resolved_by_timeout_closed_form():
    """A hung launch is reclaimed at exactly launch + timeout and the
    request re-packs immediately (zero backoff):
    latency = timeout + service = 0.25 + 0.2 = 0.45."""
    lanes = [faulty(lane(0.2), [FaultSpec("hang", launch=0)])]
    sched = sched_with(lanes, timeout=0.25, quarantine_after=5)
    sched.submit(P.make_graph(DS, 0))
    sched.drain()
    (r,) = sched.responses
    assert r.status == S.SERVED_PACKED
    assert r.latency_s == pytest.approx(0.45)
    assert sched.launches[0]["status"] == S.FAIL_TIMEOUT
    assert sched.launches[1]["status"] == "ok"


def test_hang_without_timeout_is_an_error_not_a_deadlock():
    lanes = [faulty(lane(0.2), [FaultSpec("hang", launch=0)])]
    sched = sched_with(lanes)         # launch_timeout_s = inf
    with pytest.raises(RuntimeError, match="launch_timeout_s"):
        sched.submit(P.make_graph(DS, 0))


def test_nonfinite_output_quarantines_and_reruns():
    """NaN-poisoned outputs fail the launch at completion; with
    quarantine_after=1 the lane quarantines and the batch re-runs on
    the healthy lane."""
    lanes = [faulty(lane(0.25, with_outputs=True),
                    [FaultSpec("corrupt", launch=0)]),
             lane(0.25, with_outputs=True)]
    sched = sched_with(lanes, quarantine_after=1)
    sched.submit(P.make_graph(DS, 0))
    sched.drain()
    (r,) = sched.responses
    assert r.status == S.SERVED_PACKED and r.executor == 1
    assert r.latency_s == pytest.approx(0.5)
    assert np.isfinite(r.output).all()
    assert sched.launches[0]["status"] == S.FAIL_NONFINITE
    assert sched.summary()["lane_states"][0] == S.LANE_QUARANTINED
    (q,) = [e for e in sched.events if e["kind"] == "quarantine"]
    assert q["executor"] == 0 and q["reason"] == S.FAIL_NONFINITE


def test_dead_letter_after_max_retries():
    """max_retries=2 means three failed launches dead-letter the request
    with the explicit ``failed`` status — never a hang, never a silent
    drop — while later requests still serve."""
    lanes = [faulty(lane(0.1), [FaultSpec("crash", launch=i)
                                for i in range(3)])]
    sched = sched_with(lanes, max_retries=2, quarantine_after=10)
    sched.submit(P.make_graph(DS, 0))
    sched.drain()
    (r,) = sched.responses
    assert r.status == S.FAILED
    s = sched.summary()
    assert s["failed"] == 1 and s["retries"] == 2
    assert s["failed_launches"] == 3
    sched.submit(P.make_graph(DS, 1))     # the lane still serves
    sched.drain()
    assert sched.responses[-1].status == S.SERVED_PACKED


def test_quarantine_and_probe_back_closed_form():
    """Two consecutive crashes quarantine lane 1 with probe_at exactly
    failure time + cooldown; once eligible and the healthy lane is
    busy, the next launch is the canary probe, and success returns the
    lane to the pool (with an elastic pool replan on each transition)."""
    lanes = [lane(0.2),
             faulty(lane(0.2), [FaultSpec("crash", launch=0),
                                FaultSpec("crash", launch=1)])]
    sched = sched_with(lanes, cooldown=0.3)
    sched.submit(P.make_graph(DS, 0))     # lane 0 busy
    sched.submit(P.make_graph(DS, 1))     # lane 1: crash, crash -> quarantine
    sched.drain()
    (q,) = [e for e in sched.events if e["kind"] == "quarantine"]
    assert q["executor"] == 1 and q["probe_at_s"] == pytest.approx(0.3)
    assert sched.summary()["quarantined_executors"] == [1]
    # req 1 re-packed onto the healthy lane after its 0.2 s launch
    r1 = next(r for r in sched.responses if r.req_id == 1)
    assert r1.status == S.SERVED_PACKED and r1.executor == 0
    assert r1.latency_s == pytest.approx(0.4)
    # past probe_at with lane 0 busy: the next launch is the canary
    sched.clock.advance_to(0.5)
    sched.submit(P.make_graph(DS, 2))     # lane 0
    sched.submit(P.make_graph(DS, 3))     # lane 1 probe
    sched.drain()
    probe = next(l for l in sched.launches if l["probe"])
    assert probe["executor"] == 1 and probe["status"] == "ok"
    s = sched.summary()
    assert s["probes"] == {"succeeded": 1, "failed": 0}
    assert s["lane_states"] == [S.LANE_HEALTHY, S.LANE_HEALTHY]
    assert any(e["kind"] == "probe_success" for e in sched.events)
    # pool replans rode every transition: 2 lanes -> 1 -> 2
    assert [p["n_lanes"] for p in sched.pool_events] == [2, 1, 2]


def test_last_lane_quarantine_recovers_via_probe():
    """Hard failures may quarantine the last lane; the probe-back bounds
    the outage instead of deadlocking the drain."""
    lanes = [faulty(lane(0.2), [FaultSpec("crash", launch=0),
                                FaultSpec("crash", launch=1)])]
    sched = sched_with(lanes, max_retries=5, cooldown=0.1)
    sched.submit(P.make_graph(DS, 0))
    sched.drain()                         # must terminate
    (r,) = sched.responses
    assert r.status == S.SERVED_PACKED
    # crash at t=0 twice, probe eligible at 0.1, served at 0.1 + 0.2
    assert r.latency_s == pytest.approx(0.3)
    s = sched.summary()
    assert s["probes"]["succeeded"] == 1
    assert s["lane_states"] == [S.LANE_HEALTHY]


def test_probe_failure_requarantines_with_doubled_cooldown():
    lanes = [lane(0.2),
             faulty(lane(0.2), [FaultSpec("crash", launch=i)
                                for i in range(3)])]
    sched = sched_with(lanes, cooldown=0.3, max_retries=5)
    sched.submit(P.make_graph(DS, 0))
    sched.submit(P.make_graph(DS, 1))
    sched.drain()
    sched.clock.advance_to(0.5)
    sched.submit(P.make_graph(DS, 2))     # lane 0 busy
    sched.submit(P.make_graph(DS, 3))     # lane 1 probe -> crash
    sched.drain()
    s = sched.summary()
    assert s["probes"]["failed"] == 1
    assert s["quarantined_executors"] == [1]
    q = [e for e in sched.events if e["kind"] == "quarantine"]
    assert q[-1]["reason"] == f"probe_failed:{S.FAIL_CRASH}"
    # second quarantine doubles the cooldown: probe_at = 0.5 + 0.6
    assert q[-1]["probe_at_s"] == pytest.approx(1.1)
    # the probed request still resolved on the healthy lane
    r3 = next(r for r in sched.responses if r.req_id == 3)
    assert r3.status == S.SERVED_PACKED and r3.executor == 0


def test_validate_rejects_malformed_graph_at_admission():
    g = P.make_graph(DS, 0)
    nf = np.array(g.node_feat, copy=True)
    nf[0, 0] = np.nan
    bad = dataclasses.replace(g, node_feat=nf)
    sched = sched_with([lane(0.1)], validate=True)
    sched.submit(bad)
    sched.submit(P.make_graph(DS, 1))
    sched.drain()
    by_id = {r.req_id: r for r in sched.responses}
    assert by_id[0].status == S.REJECTED_INVALID
    assert by_id[1].status == S.SERVED_PACKED
    (ev,) = [e for e in sched.events if e["kind"] == "rejected_invalid"]
    assert "non-finite node features" in ev["reason"]
    assert sched.summary()["rejected_invalid"] == 1


# ------------------------------------------------- exactly-once property --

def _chaos_exactly_once_body(seed: int, n: int, load: float,
                             fault_scale: float):
    """Under seed-driven crash+hang+corrupt+slowdown injection (>= 10%
    of launches at fault_scale >= 1) plus malformed and oversize
    arrivals, every submitted request resolves to exactly one terminal
    status — none lost, none duplicated — and quarantined lanes never
    deadlock the drain."""
    rates = {k: v * fault_scale for k, v in
             {"crash": 0.06, "hang": 0.04, "corrupt": 0.06,
              "slowdown": 0.04}.items()}
    cfg = S.SchedulerConfig(
        64, 1000, max_graphs=4, max_queue_depth=64,
        default_tier=S.SLOTier("standard", 0.02, 1),
        launch_timeout_s=0.05, max_retries=2, retry_backoff_s=0.005,
        retry_backoff_cap_s=0.04, quarantine_after=2,
        quarantine_cooldown_s=0.05, quarantine_cooldown_cap_s=0.4,
        validate=True)
    clock = S.VirtualClock()
    lanes = [FaultyExecutor(
        S.SimExecutor(S.constant_service(0.01),
                      batch_fn=lambda b: np.zeros(
                          (len(b["graph_valid"]), 1), np.float32),
                      fallback_fn=lambda g: np.zeros((1,), np.float32)),
        FaultPlan.random(seed=seed * 3 + i, n_calls=4 * n, rates=rates),
        clock) for i in range(3)]
    sched = S.ContinuousScheduler(cfg, lanes, clock=clock)
    trace = S.poisson_trace(n, load, DS, seed=seed)

    def mangle(i, g):
        if i % 11 == 5:       # oversize: rides the fallback lanes
            return dataclasses.replace(g, num_nodes=70)
        if i % 13 == 7:       # malformed: rejected at admission
            nf = np.array(g.node_feat, copy=True)
            nf[0, 0] = np.inf
            return dataclasses.replace(g, node_feat=nf)
        return g
    trace = [(t, mangle(i, g), tn) for i, (t, g, tn) in enumerate(trace)]
    S.run_trace(sched, trace)
    assert sorted(r.req_id for r in sched.responses) == list(range(n))
    s = sched.summary()
    terminal = (s["served"] + s["rejected_queue_full"]
                + s["rejected_oversize"] + s["rejected_invalid"]
                + s["failed"])
    assert terminal == n
    for i in range(n):
        if i % 11 != 5 and i % 13 == 7:
            r = next(r for r in sched.responses if r.req_id == i)
            assert r.status == S.REJECTED_INVALID
    return s


def test_chaos_exactly_once_randomized_sweep():
    rng = np.random.default_rng(1)
    doses = []
    for seed in range(10):
        s = _chaos_exactly_once_body(
            seed, n=int(rng.integers(20, 80)),
            load=float(rng.uniform(50, 600)),
            fault_scale=float(rng.uniform(0.5, 2.5)))
        doses.append(s["failed_launches"])
    assert sum(doses) > 0, "the sweep never actually injected a failure"


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=hst.integers(0, 2**16), n=hst.integers(5, 60),
           load=hst.floats(20.0, 600.0),
           fault_scale=hst.floats(0.25, 2.5))
    def test_chaos_exactly_once_hypothesis(seed, n, load, fault_scale):
        _chaos_exactly_once_body(seed, n, load, fault_scale)
else:
    @needs_hypothesis
    def test_chaos_exactly_once_hypothesis():
        pass  # covered by test_chaos_exactly_once_randomized_sweep above
