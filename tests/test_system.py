"""End-to-end behaviour tests for the framework.

1. GNNBuilder pipeline (the paper's Listing-1 flow): model -> generated
   program -> testbench -> synthesis report -> DSE.
2. LM training end-to-end: loss decreases over real optimizer steps.
3. Serve path cache padding.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn import DATASETS, config
from repro.core import dse
from repro.core import perf_model as PM
from repro.core.project import Project
from repro.core.quantization import FPX
from repro.configs.registry import get_config
from repro.data.pipeline import TokenDataConfig, token_batch
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as steps_mod
from repro.models import lm
from repro.nn import param as prm
from repro.optim import adamw


def test_gnnbuilder_listing1_flow(tmp_path):
    """The paper's end-to-end user story in one test."""
    cfg = config("sage", reduced=True)
    proj = Project("e2e", cfg, "classification", str(tmp_path),
                   dataset_cfg=DATASETS["qm9"], float_or_fixed="fixed",
                   fpx=FPX(16, 10))
    proj.gen_hw_model()
    proj.init_params()
    assert proj.gen_testbench(8) == 8
    tb = proj.build_and_run_testbench()
    assert tb["mae"] < 1.0 and tb["mean_runtime_ms"] > 0
    synth = proj.run_vitis_hls_synthesis()
    assert synth["latency_s"] > 0 and synth["flops"] > 0
    assert synth["fits_hbm"]
    assert (tmp_path / "report.json").exists()
    assert (tmp_path / "config.json").exists()


def test_dse_database_fit_explore(tmp_path):
    """Mini version of the paper's §VIII-A protocol: synthesize designs,
    fit direct-fit models, explore faster than synthesis."""
    db = dse.build_database(12, str(tmp_path), seed=0, log=None)
    models = dse.fit_models(db)
    best = dse.explore(models, n_candidates=256, seed=1)
    assert best["pred_latency_s"] > 0
    assert best["ms_per_eval"] < 50          # model eval is ~ms-scale
    x = np.stack([PM.features(d) for d in db])
    y = np.array([d["latency_s"] for d in db])
    # in-sample sanity: direct-fit model beats the mean predictor
    assert PM.mape(y, models.latency.predict(x)) < PM.mape(
        y, np.full_like(y, y.mean()))


def test_lm_train_loss_decreases():
    cfg = get_config("qwen3-8b", reduced=True)
    mesh = make_host_mesh()
    bundle = steps_mod.make_train_step(
        cfg, mesh, opt_cfg=adamw.OptConfig(peak_lr=3e-3, warmup_steps=5,
                                           decay_steps=60),
        seq=32, batch=8)
    step = bundle.jit()
    plan = lm.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    opt = prm.materialize(adamw.opt_plan(plan), jax.random.key(1))
    data_cfg = TokenDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=8)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in
                 token_batch(data_cfg, i).items() if k != "mask"}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.1, losses[::8]


def test_serve_cache_padding():
    from repro.launch.serve import pad_caches
    full = {"k": jnp.zeros((2, 16, 4), jnp.bfloat16)}
    part = {"k": jnp.ones((2, 8, 4), jnp.float32)}
    out = pad_caches(part, full)
    assert out["k"].shape == (2, 16, 4) and out["k"].dtype == jnp.bfloat16
    assert float(out["k"][0, 7, 0]) == 1.0
    assert float(out["k"][0, 8, 0]) == 0.0
