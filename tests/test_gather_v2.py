"""Gather-v2 kernel tier: the one-hot-free DMA kernel against its
pure-jnp oracle and the XLA baseline (aggregation x scale x degenerate
grids), gather_mode dispatch and backend-scope routing, the multi-layer
VMEM-residency path against layer-by-layer apply_packed across
conv x precision x task, the residency_plan budget rule, Project
config.json recording, honest gather cost modeling, and DSE
featurization of the new knobs (legacy databases included)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregations as A
from repro.core import convs as C
from repro.core import dse
from repro.core import gnn_model as G
from repro.core import perf_model as PM
from repro.core.aggregations import GATHER_AGGREGATIONS
from repro.core.project import Project
from repro.data import pipeline as P
from repro.kernels.fused_gather_aggregate.kernel import (
    fused_gather_aggregate_v2_pallas)
from repro.kernels.fused_gather_aggregate.ops import (
    GATHER_MODES, fused_gather_aggregate)
from repro.kernels.fused_gather_aggregate.ref import (
    fused_gather_aggregate_ref, fused_gather_aggregate_v2_ref)
from repro.kernels.fused_gather_aggregate.residency import (
    RESIDENT_KINDS, fused_layer_stack_pallas)
from repro.nn import param as prm

DS = P.GraphDataConfig(avg_nodes=10, max_nodes=64, max_edges=64,
                       node_feat_dim=11, edge_feat_dim=4, seed=5)


def _stream(n=37, e=91, f=5, seed=0, pad_every=7, oob_every=11):
    """Non-divisible shapes, interleaved -1 padding, and out-of-range
    ids on both streams (the wrapper must kill those edges whole)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    if pad_every:
        src[::pad_every] = -1
        dst[::pad_every] = -1
    if oob_every:
        src[3::oob_every] = n + 7
        dst[5::oob_every] = n + 3
    scale = jnp.asarray(rng.uniform(0.5, 2.0, e), jnp.float32)
    return x, jnp.asarray(src), jnp.asarray(dst), scale


def _packed_batch(seed0=0):
    gs = [P.make_graph(DS, i) for i in range(5)]
    batch, k = P.pack_graphs(gs, 128, 256, 8)
    assert k == len(gs)
    return {kk: jnp.asarray(v) for kk, v in batch.items() if kk != "y"}


def _cfg(conv, prec="fp32", task="graph", skip=True, nl=3):
    return G.GNNModelConfig(
        graph_input_feature_dim=11, graph_input_edge_dim=4,
        gnn_hidden_dim=16, gnn_num_layers=nl, gnn_output_dim=8,
        gnn_conv=conv, task=task, gnn_precision=prec,
        gnn_skip_connection=skip,
        mlp_head=G.MLPConfig(in_dim=24, out_dim=1, hidden_dim=8,
                             hidden_layers=1) if task == "graph" else None)


# ------------------------------------------------- v2 kernel parity -----
@pytest.mark.parametrize("agg", GATHER_AGGREGATIONS)
@pytest.mark.parametrize("with_scale", [False, True])
def test_v2_kernel_matches_oracle_and_legacy(agg, with_scale):
    """v2 kernel == v2 oracle == legacy one-hot oracle on a
    non-divisible shape with padding and out-of-range ids."""
    x, src, dst, scale = _stream()
    sc = scale if with_scale else None
    got = np.asarray(fused_gather_aggregate_v2_pallas(
        x, src, dst, 37, scale=sc, agg=agg, edge_block=32))
    ref = np.asarray(fused_gather_aggregate_v2_ref(
        x, src, dst, 37, scale=sc, agg=agg))
    legacy = np.asarray(fused_gather_aggregate_ref(
        x, src, dst, 37, scale=sc, agg=agg))
    np.testing.assert_allclose(got, ref, atol=1e-5)
    np.testing.assert_allclose(got, legacy, atol=1e-5)


@pytest.mark.parametrize("agg", GATHER_AGGREGATIONS)
def test_v2_degenerate_empty_edges(agg):
    """Zero-length edge stream: all-zero output at the right shape."""
    x = jnp.ones((9, 4), jnp.float32)
    empty = jnp.zeros((0,), jnp.int32)
    out = np.asarray(fused_gather_aggregate_v2_pallas(
        x, empty, empty, 9, agg=agg))
    assert out.shape == (9, 4)
    assert np.all(out == 0.0)


@pytest.mark.parametrize("agg", GATHER_AGGREGATIONS)
def test_v2_degenerate_all_padding(agg):
    """Every edge is padding (the all-padding trailing blocks of a
    packed batch): min/max neutral elements must flush to zero."""
    x, _, _, scale = _stream(e=64)
    pad = jnp.full((64,), -1, jnp.int32)
    out = np.asarray(fused_gather_aggregate_v2_pallas(
        x, pad, pad, 37, scale=scale, agg=agg))
    assert np.all(out == 0.0)


@pytest.mark.parametrize("agg", GATHER_AGGREGATIONS)
def test_v2_isolated_nodes(agg):
    """Destinations never touched by an edge stay exactly zero."""
    x = jnp.asarray(np.random.default_rng(3).standard_normal((16, 6)),
                    jnp.float32)
    src = jnp.asarray([0, 1, 2, 3], jnp.int32)
    dst = jnp.asarray([5, 5, 7, 7], jnp.int32)
    out = np.asarray(fused_gather_aggregate_v2_pallas(
        x, src, dst, 16, agg=agg))
    touched = {5, 7}
    for i in range(16):
        if i not in touched:
            assert np.all(out[i] == 0.0), i
    ref = np.asarray(fused_gather_aggregate_v2_ref(
        x, src, dst, 16, agg=agg))
    np.testing.assert_allclose(out, ref, atol=1e-6)


def test_v2_zero_segments():
    x, src, dst, _ = _stream()
    out = np.asarray(fused_gather_aggregate_v2_pallas(
        x, src, dst, 0, agg="sum"))
    assert out.shape == (0, 5)


# ---------------------------------------------- gather_mode dispatch ----
def test_ops_dispatches_both_generations():
    x, src, dst, scale = _stream()
    for mode in GATHER_MODES:
        got = np.asarray(fused_gather_aggregate(
            x, src, dst, None, scale, num_segments=37, agg="sum",
            gather_mode=mode))
        ref = np.asarray(fused_gather_aggregate(
            x, src, dst, None, scale, num_segments=37, agg="sum",
            use_pallas=False, gather_mode=mode))
        np.testing.assert_allclose(got, ref, atol=1e-5)
    with pytest.raises(ValueError, match="gather_mode"):
        fused_gather_aggregate(x, src, dst, None, None, num_segments=37,
                               gather_mode="bogus")


def test_backend_scope_routes_gather_mode():
    """backend_scope(gather_mode=...) reroutes gather_aggregate between
    kernel generations; both match the XLA baseline."""
    x, src, dst, scale = _stream()
    base = np.asarray(A.gather_aggregate("sum", x, src, dst, 37,
                                         src >= 0, scale, backend="xla"))
    for mode in GATHER_MODES:
        with A.backend_scope("pallas", gather_mode=mode):
            got = np.asarray(A.gather_aggregate("sum", x, src, dst, 37,
                                                src >= 0, scale))
        np.testing.assert_allclose(got, base, atol=1e-5, err_msg=mode)
    with pytest.raises(ValueError):
        A.set_default_backend("pallas", gather_mode="bogus")


@pytest.mark.parametrize("conv", ["gcn", "sage", "gin", "pna"])
@pytest.mark.parametrize("prec", ["fp32", "bf16", "int8"])
def test_packed_model_v2_vs_xla(conv, prec):
    """apply_packed with the v2 kernel == XLA backend for every conv at
    every precision (the dispatch the serving path takes by default)."""
    cfg = _cfg(conv, prec, nl=2)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    jb = _packed_batch()
    pol = G.calibrated_policy(params, cfg, jb) if prec == "int8" else None
    with A.backend_scope("xla"):
        ref = np.asarray(G.apply_packed(params, cfg, jb, policy=pol))
    with A.backend_scope("pallas", gather_mode="dma"):
        got = np.asarray(G.apply_packed(params, cfg, jb, policy=pol))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=5e-5)


# ------------------------------------------------- residency parity -----
def _resident_tols(prec, pol):
    if prec == "fp32":
        return 1e-5, 0.0
    if prec == "bf16":
        return 5e-2, 1e-2       # bf16 keeps ~3 significant digits
    # int8: the resident backbone's sub-grid perturbations can cross one
    # head-input grid boundary on graph tasks; tolerate one grid step
    fpx = pol.head.in_fpx or pol.head.act_fpx
    return 5e-2, 1.05 * fpx.resolution


@pytest.mark.parametrize("conv", RESIDENT_KINDS)
@pytest.mark.parametrize("prec", ["fp32", "bf16", "int8"])
@pytest.mark.parametrize("task", ["graph", "node"])
def test_resident_matches_layerwise(conv, prec, task):
    """Multi-layer VMEM residency == layer-by-layer apply_packed within
    the documented dtype tolerances, for both resident conv kinds at
    every precision, graph and node tasks."""
    cfg = _cfg(conv, prec, task)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    jb = _packed_batch()
    pol = G.calibrated_policy(params, cfg, jb) if prec == "int8" else None
    ref = np.asarray(G.apply_packed(params, cfg, jb, policy=pol))
    got = np.asarray(G.apply_packed_resident(params, cfg, jb, policy=pol,
                                             fusion_depth=2))
    rtol, atol = _resident_tols(
        prec, G.resolve_policy(cfg, pol) if prec == "int8" else None)
    # tolerance against the output scale, not elementwise: rounded
    # dtypes legitimately perturb near-zero elements by absolute amounts
    # proportional to the tensor's dynamic range
    err = np.max(np.abs(got - ref))
    bound = rtol * np.max(np.abs(ref)) + atol
    assert err <= bound, (err, bound)


@pytest.mark.parametrize("skip", [True, False])
def test_resident_skip_variants(skip):
    cfg = _cfg("gcn", skip=skip)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(1))
    jb = _packed_batch()
    ref = np.asarray(G.apply_packed(params, cfg, jb))
    got = np.asarray(G.apply_packed_resident(params, cfg, jb,
                                             fusion_depth=3))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_resident_depth_sweep():
    """Any fusion depth groups to the same answer; depth 1 falls back to
    apply_packed bit-exactly."""
    cfg = _cfg("sage", nl=4)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(2))
    jb = _packed_batch()
    ref = np.asarray(G.apply_packed(params, cfg, jb))
    for fd in (1, 2, 3, 4, 9):
        got = np.asarray(G.apply_packed_resident(params, cfg, jb,
                                                 fusion_depth=fd))
        if fd == 1:
            np.testing.assert_array_equal(got, ref)
        else:
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_resident_fallback_for_nonlinear_conv():
    """GIN cannot run resident (nonlinear gamma-MLP): the planner says
    no and the fallback is bit-exact apply_packed."""
    cfg = _cfg("gin")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    jb = _packed_batch()
    np.testing.assert_array_equal(
        np.asarray(G.apply_packed_resident(params, cfg, jb,
                                           fusion_depth=2)),
        np.asarray(G.apply_packed(params, cfg, jb)))


def test_resident_kernel_empty_edges():
    """A graph with no edges still runs the layer boundary math (bias,
    self term, skip, activation)."""
    cfg = _cfg("gcn")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(3))
    gs = [P.make_graph(DS, 0)]
    batch, _ = P.pack_graphs(gs, 64, 64, 4)
    jb = {k: jnp.asarray(v) for k, v in batch.items() if k != "y"}
    jb["edge_index"] = jnp.full_like(jb["edge_index"], -1)
    ref = np.asarray(G.apply_packed(params, cfg, jb))
    got = np.asarray(G.apply_packed_resident(params, cfg, jb,
                                             fusion_depth=2))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------- planner rule ------
def test_residency_plan_budget_rule():
    dims = [(11, 16), (16, 16), (16, 8)]
    ok = C.residency_plan(dims, 128, "gcn", 2)
    assert ok.legal and ok.depth == 2 and ok.fmax == 128
    assert ok.vmem_required <= ok.vmem_budget
    # over budget: a node table that cannot fit
    over = C.residency_plan(dims, 10**7, "gcn", 2)
    assert not over.legal and "exceeds" in over.reason
    # explicit tiny budget
    tiny = C.residency_plan(dims, 128, "gcn", 2, vmem_bytes=1024)
    assert not tiny.legal
    # non-resident conv and depth < 2
    assert not C.residency_plan(dims, 128, "pna", 2).legal
    assert not C.residency_plan(dims, 128, "gcn", 1).legal
    # depth clamps to the stack
    assert C.residency_plan(dims, 128, "sage", 9).depth == 3
    # the planner's conv list matches the kernel's
    assert C.RESIDENT_CONVS == RESIDENT_KINDS


def test_gather_cost_model_honesty():
    """The gather compute term makes the one-hot kernel compute-bound in
    the model, as it is on the clock: dma FLOPs are linear in E*F and
    orders of magnitude below onehot at realistic node counts."""
    n, e, f = 872, 1736, 64
    dma = C.gather_compute_flops(n, e, f, "dma")
    onehot = C.gather_compute_flops(n, e, f, "onehot")
    assert dma == 3.0 * e * f
    assert onehot > 1000 * dma
    with pytest.raises(ValueError):
        C.gather_compute_flops(n, e, f, "bogus")
    # dataflow_cost stays honest under both generations and its
    # ordering decision is unchanged for the (negligible) dma term
    base = C.dataflow_cost(16, 64, 2.0)
    oh = C.dataflow_cost(16, 64, 2.0, gather_mode="onehot")
    assert oh["aggregate_first"] > base["aggregate_first"]
    assert base["aggregate_first"] < base["transform_first"]


# --------------------------------------------- Project + DSE wiring -----
def test_project_records_residency(tmp_path):
    cfg = _cfg("gcn", nl=2)
    proj = Project("res_rec", cfg, "dse", str(tmp_path), max_nodes=64,
                   max_edges=64, batch_graphs=4, agg_backend="pallas",
                   gather_mode="dma", fusion_depth=2)
    proj.gen_hw_model()
    rec = json.load(open(tmp_path / "config.json"))
    assert rec["gather_mode"] == "dma"
    assert rec["fusion_depth"] == 2
    assert rec["residency"]["legal"] is True
    assert rec["residency_engaged"] is True
    assert "fits" in rec["residency"]["reason"]


def test_project_residency_needs_pallas(tmp_path):
    """fusion_depth > 1 with the XLA backend: plan recorded, resident
    program NOT engaged (the resident path is a Pallas kernel)."""
    cfg = _cfg("gcn", nl=2)
    proj = Project("res_xla", cfg, "dse", str(tmp_path), max_nodes=64,
                   max_edges=64, batch_graphs=4, agg_backend="xla",
                   fusion_depth=2)
    proj.gen_hw_model()
    rec = json.load(open(tmp_path / "config.json"))
    assert rec["residency"]["legal"] is True
    assert rec["residency_engaged"] is False
    with pytest.raises(ValueError, match="gather_mode"):
        Project("bad", cfg, "dse", str(tmp_path), gather_mode="bogus")


def test_dse_space_and_featurization():
    """The new knobs are searchable and featurized; legacy design dicts
    (no gather_mode / fusion_depth keys) still featurize, defaulting to
    what they executed with: the one-hot kernel, no fusion."""
    assert set(dse.SPACE["gather_mode"]) == set(GATHER_MODES)
    assert 1 in dse.SPACE["fusion_depth"]
    names = PM.FEATURE_NAMES
    i_dma, i_fd = names.index("gather_dma"), names.index("fusion_depth")
    rng = np.random.default_rng(0)
    d = dse.sample_design(rng)
    v = PM.features(d)
    assert len(v) == len(names)
    assert v[i_dma] == (1.0 if d["gather_mode"] == "dma" else 0.0)
    assert v[i_fd] == float(d["fusion_depth"])
    legacy = {k: val for k, val in d.items()
              if k not in ("gather_mode", "fusion_depth")}
    lv = PM.features(legacy)
    assert lv[i_dma] == 0.0 and lv[i_fd] == 1.0
