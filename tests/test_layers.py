"""Layer-level numerics: chunked attention vs dense reference, chunked
cross-entropy vs full softmax, norms, rope, MoE routing properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn import moe as MOE
from repro.nn import param as prm

RNG = np.random.default_rng(7)


def test_online_attention_matches_dense():
    b, h, s, d = 2, 3, 64, 16
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    got = A.online_attention(q, k, v, causal=True, chunk=16)
    # dense reference
    sc = jnp.einsum("bhqd,bhkd->bhqk", q, k) * d ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask, sc, -1e30)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_online_attention_ragged_chunk():
    b, h, s, d = 1, 2, 50, 8     # 50 % 16 != 0 -> padding path
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    k, v = q + 0.1, q - 0.1
    got = A.online_attention(q, k, v, causal=False, chunk=16)
    sc = jnp.einsum("bhqd,bhkd->bhqk", q, k) * d ** -0.5
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_chunked_xent_matches_full():
    b, s, d, v = 2, 32, 8, 50
    x = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((d, v)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, v, (b, s)), jnp.int32)
    loss_c, _ = L.chunked_softmax_xent(x, w, labels, chunk=8)
    logits = x @ w
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(logz - gold)
    np.testing.assert_allclose(float(loss_c), float(want), rtol=1e-5)


def test_chunked_xent_mask():
    b, s, d, v = 1, 16, 4, 11
    x = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((d, v)), jnp.float32)
    labels = jnp.zeros((b, s), jnp.int32)
    mask = jnp.zeros((b, s)).at[:, :4].set(1.0)
    loss_m, wsum = L.chunked_softmax_xent(x, w, labels, chunk=8,
                                          label_mask=mask)
    assert float(wsum) == 4.0
    loss_f, _ = L.chunked_softmax_xent(x[:, :4], w, labels[:, :4], chunk=4)
    np.testing.assert_allclose(float(loss_m), float(loss_f), rtol=1e-5)


def test_rope_preserves_norm_and_relative_phase():
    s, d = 16, 8
    x = jnp.asarray(RNG.standard_normal((s, d)), jnp.float32)
    pos = jnp.arange(s)
    y = A.rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # dot products depend only on relative positions
    q = jnp.ones((1, d), jnp.float32)
    k = jnp.ones((1, d), jnp.float32)
    d1 = A.rope(q, jnp.array([3]), 1e4) @ A.rope(k, jnp.array([5]), 1e4).T
    d2 = A.rope(q, jnp.array([10]), 1e4) @ A.rope(k, jnp.array([12]), 1e4).T
    np.testing.assert_allclose(d1, d2, rtol=1e-4)


def test_rmsnorm_scale_invariance_of_direction():
    x = jnp.asarray(RNG.standard_normal((4, 16)), jnp.float32)
    p = {"scale": jnp.ones((16,), jnp.float32)}
    y1, y2 = L.rmsnorm(p, x), L.rmsnorm(p, 3.0 * x)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------ MoE --
def _moe_setup(e=4, k=2, d=16, f=32, b=2, s=16, cap_factor=8.0):
    cfg = MOE.MoEConfig(d_model=d, num_experts=e, top_k=k, d_ff_expert=f,
                        capacity_factor=cap_factor)
    plan = MOE.moe_plan(cfg, jnp.float32)
    params = prm.materialize(plan, jax.random.key(0))
    x = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    return cfg, params, x


def test_moe_high_capacity_matches_dense_dispatch():
    """With capacity >= S, no tokens drop: output == explicit per-token
    weighted sum over the top-k experts."""
    cfg, params, x = _moe_setup()
    y, aux = MOE.moe_forward(params, x, cfg)

    gates = x @ params["router"]
    probs = jax.nn.softmax(gates, -1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / topv.sum(-1, keepdims=True)

    def expert(ei, xt):
        h = (xt @ params["w_up"][ei]) * jax.nn.silu(
            xt @ params["w_gate"][ei])
        return h @ params["w_down"][ei]

    want = jnp.zeros_like(x)
    for bi in range(x.shape[0]):
        for si in range(x.shape[1]):
            acc = jnp.zeros((cfg.d_model,))
            for kk in range(cfg.top_k):
                e = int(topi[bi, si, kk])
                acc += topv[bi, si, kk] * expert(e, x[bi, si])
            want = want.at[bi, si].set(acc)
    np.testing.assert_allclose(y, want, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_overflow():
    cfg, params, x = _moe_setup(cap_factor=0.5)
    y, _ = MOE.moe_forward(params, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_moe_aux_loss_balanced_router_is_low():
    """A uniform router gives aux ~= num_experts * k/E * ... ~ k."""
    cfg, params, x = _moe_setup()
    params = dict(params, router=jnp.zeros_like(params["router"]))
    _, aux = MOE.moe_forward(params, x, cfg)
    assert float(aux) <= cfg.top_k + 0.3
