"""Fixed-point (FPX) quantization properties. Skipped (not errored) on
machines without hypothesis so the tier-1 suite still collects."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.quantization import FPX, quantize, quantize_tree

settings.register_profile("fast", max_examples=50, deadline=None)
settings.load_profile("fast")

fpx_strategy = st.builds(
    FPX,
    w=st.sampled_from([8, 16, 24, 32]),
    i=st.integers(2, 8),
)


@given(st.floats(-100, 100, allow_nan=False, width=32), fpx_strategy)
def test_quantize_idempotent(x, fpx):
    q1 = float(quantize(jnp.float32(x), fpx))
    q2 = float(quantize(jnp.float32(q1), fpx))
    assert q1 == q2


@given(st.floats(-1.875, 1.875, allow_nan=False, width=32), fpx_strategy)
def test_error_bounded_by_half_resolution(x, fpx):
    if abs(x) > fpx.max_val:
        return
    q = float(quantize(jnp.float32(x), fpx))
    # emulation runs in f32: allow f32 rounding noise on very fine grids
    bound = max(fpx.resolution / 2, abs(x) * 2 ** -22) + 1e-9
    assert abs(q - x) <= bound


@given(st.floats(-1e6, 1e6, allow_nan=False), fpx_strategy)
def test_saturation(x, fpx):
    q = float(quantize(jnp.float32(x), fpx))
    slack = max(fpx.resolution, abs(fpx.max_val) * 2 ** -22)
    assert fpx.min_val - slack <= q <= fpx.max_val + slack


def test_quantize_tree_skips_ints():
    tree = {"w": jnp.ones((3,), jnp.float32) * 0.123456,
            "idx": jnp.arange(3, dtype=jnp.int32)}
    out = quantize_tree(tree, FPX(8, 4))
    assert out["idx"].dtype == jnp.int32
    assert float(out["w"][0]) != 0.123456  # actually quantized


def test_paper_formats():
    assert FPX(32, 16).frac_bits == 16
    assert FPX(16, 10).resolution == 2 ** -6
    assert str(FPX(16, 10)) == "fpx<16,10>"
