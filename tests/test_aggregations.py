"""Property-based tests (hypothesis) for the paper's partial aggregations:
permutation invariance, Welford == two-pass variance, streaming == segment
forms, and degree-table correctness. Skipped (not errored) on machines
without hypothesis so the tier-1 suite still collects."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import aggregations as A

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")

floats = st.floats(-100, 100, allow_nan=False, width=32)


@st.composite
def neighbor_sets(draw):
    n = draw(st.integers(1, 12))
    dim = draw(st.integers(1, 5))
    xs = draw(st.lists(st.lists(floats, min_size=dim, max_size=dim),
                       min_size=n, max_size=n))
    return np.array(xs, np.float32)


@given(neighbor_sets(), st.permutations(range(5)),
       st.sampled_from(A.AGGREGATIONS))
def test_permutation_invariance(xs, perm5, agg):
    perm = np.argsort(np.resize(perm5, len(xs)) + np.arange(len(xs)) * 0.1)
    a = A.aggregate_stream(agg, jnp.asarray(xs))
    b = A.aggregate_stream(agg, jnp.asarray(xs[perm]))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@given(neighbor_sets())
def test_welford_equals_two_pass(xs):
    got = np.asarray(A.aggregate_stream("var", jnp.asarray(xs)))
    want = xs.var(axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@given(neighbor_sets(), st.sampled_from(A.AGGREGATIONS))
def test_stream_equals_segment(xs, agg):
    """Streaming (kernel) form == segment (XLA) form on one segment."""
    n = len(xs)
    seg = jnp.zeros((n,), jnp.int32)
    got = A.segment_aggregate(agg, jnp.asarray(xs), seg, 1)[0]
    want = A.aggregate_stream(agg, jnp.asarray(xs))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@given(st.integers(2, 20), st.integers(1, 40), st.integers(0, 10**6))
def test_degrees_match_numpy(n, e, seed):
    rng = np.random.default_rng(seed)
    ei = np.full((e + 4, 2), -1, np.int32)
    ei[:e, 0] = rng.integers(0, n, e)
    ei[:e, 1] = rng.integers(0, n, e)
    indeg, outdeg = A.degrees(jnp.asarray(ei), n)
    want_in = np.bincount(ei[:e, 1], minlength=n)
    want_out = np.bincount(ei[:e, 0], minlength=n)
    np.testing.assert_array_equal(np.asarray(indeg), want_in)
    np.testing.assert_array_equal(np.asarray(outdeg), want_out)


def test_segment_padding_dropped():
    msgs = jnp.ones((4, 2), jnp.float32)
    seg = jnp.array([0, 0, 1, 1], jnp.int32)
    valid = jnp.array([True, True, True, False])
    out = A.segment_aggregate("sum", msgs, seg, 2, valid)
    np.testing.assert_allclose(out, [[2, 2], [1, 1]])
