"""Continuous-batching scheduler: deterministic virtual-clock tests.

Everything here runs on the injected ``VirtualClock`` — closed-form
latency assertions, scripted launch policies, straggler eviction, and
randomized exactly-once sweeps all replay bit-identically with zero
sleeps. The property tests run twice: seeded numpy sweeps always, and
hypothesis-driven versions when hypothesis is installed (guarded
import; the container image does not ship it).
"""
import dataclasses

import numpy as np
import pytest

from repro.data import pipeline as P
from repro.runtime import scheduler as S

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(not HAS_HYPOTHESIS,
                                      reason="hypothesis not installed")

DS = P.GraphDataConfig(avg_nodes=8, avg_degree=2, node_feat_dim=5,
                       edge_feat_dim=3, max_nodes=64, max_edges=64, seed=3)


def sized(idx: int, n_nodes: int, n_edges: int = 4) -> P.Graph:
    """A graph with exact accounting sizes (contents irrelevant to the
    pure-latency tests, which run with batch_fn=None)."""
    g = P.make_graph(DS, idx)
    return dataclasses.replace(g, num_nodes=n_nodes, num_edges=n_edges)


def sim_sched(service: float = 1.0, *, max_graphs: int = 4,
              node_budget: int = 1000, edge_budget: int = 1000,
              deadline: float = 0.25, depth: int = 256, n_lanes: int = 1,
              allow_fallback: bool = True, tiers=None,
              service_per_lane=None) -> S.ContinuousScheduler:
    cfg = S.SchedulerConfig(node_budget, edge_budget, max_graphs,
                            max_queue_depth=depth, tiers=tiers,
                            default_tier=S.SLOTier("standard", deadline, 1))
    svcs = service_per_lane or [service] * n_lanes
    lanes = [S.SimExecutor(S.constant_service(s),
                           allow_fallback=allow_fallback) for s in svcs]
    return S.ContinuousScheduler(cfg, lanes)


# ---------------------------------------------------------------- metrics --

def test_percentile_nearest_rank():
    v = list(range(1, 11))
    assert S.percentile(v, 50) == 5.0       # ceil(0.50 * 10) = 5th
    assert S.percentile(v, 90) == 9.0
    assert S.percentile(v, 99) == 10.0      # ceil(0.99 * 10) = 10th
    assert S.percentile(v, 100) == 10.0
    assert S.percentile([7.0], 1) == 7.0
    assert S.percentile([7.0], 99) == 7.0
    assert S.percentile([3.0, 1.0, 2.0], 50) == 2.0
    assert S.percentile([], 50) is None     # explicit null, never NaN


def test_summarize_empty():
    s = S.summarize([])
    assert s["served"] == 0
    assert s["graphs_per_s"] == 0.0
    assert s["p50_latency_s"] is None       # JSON null, never NaN
    assert s["p99_latency_s"] is None
    assert s["mean_latency_s"] is None
    assert s["max_latency_s"] is None


def test_virtual_clock_monotonic():
    c = S.VirtualClock(1.0)
    assert c.now() == 1.0
    c.advance_to(2.5)
    assert c.now() == 2.5
    with pytest.raises(ValueError):
        c.advance_to(1.0)


# ---------------------------------------------------------- launch policy --

def test_closed_form_burst_latency():
    """10 simultaneous arrivals, max_graphs=4, service 1.0, deadline
    0.25: batch 4 launches at t=0 (budget-full), batch 4 at t=1, batch 2
    at t=2 -> latencies [1 x4, 2 x4, 3 x2], every figure closed-form."""
    sched = sim_sched(1.0, max_graphs=4, deadline=0.25)
    trace = [(0.0, P.make_graph(DS, i), "default") for i in range(10)]
    S.run_trace(sched, trace)
    s = sched.summary()
    assert s["n_launches"] == 3
    assert [len(l["req_ids"]) for l in sched.launches] == [4, 4, 2]
    lat = sorted(r.latency_s for r in sched.responses)
    assert lat == pytest.approx([1.0] * 4 + [2.0] * 4 + [3.0] * 2)
    assert s["p50_latency_s"] == pytest.approx(2.0)
    assert s["p99_latency_s"] == pytest.approx(3.0)
    assert s["mean_latency_s"] == pytest.approx(1.8)
    assert s["mean_batch_fill"] == pytest.approx(10 / 12)
    assert s["graphs_per_s"] == pytest.approx(10 / 3)


def test_deadline_expiry_fires_launch():
    """A lone request launches when its tier deadline expires — latency
    is exactly deadline + service on the virtual clock."""
    sched = sim_sched(1.0, deadline=0.25)
    sched.submit(P.make_graph(DS, 0))
    assert sched.next_event_s() == pytest.approx(0.25)
    sched.clock.advance_to(0.25)
    sched.tick()
    assert sched.inflight and not sched.pending
    sched.drain()
    assert sched.responses[0].latency_s == pytest.approx(1.25)


def test_budget_full_fires_before_deadline():
    sched = sim_sched(1.0, max_graphs=4, deadline=10.0)
    for i in range(4):
        sched.submit(P.make_graph(DS, i))
    assert sched.inflight, "max_graphs reached must launch immediately"
    sched.drain()
    assert all(r.latency_s == pytest.approx(1.0) for r in sched.responses)


def test_blocked_request_repacks_into_next_launch():
    """Node budget fits two 10-node graphs; the third marks the batch
    full (immediate launch) and re-packs into the next one — the
    straggler rule."""
    sched = sim_sched(1.0, max_graphs=8, node_budget=25, deadline=10.0)
    for i in range(3):
        sched.submit(sized(i, 10))
    sched.drain()
    assert [l["req_ids"] for l in sched.launches] == [[0, 1], [2]]
    r2 = next(r for r in sched.responses if r.req_id == 2)
    assert r2.batch_seq == 1 and r2.status == S.SERVED_PACKED


def test_slo_priority_packs_premium_first():
    """Premium outranks earlier-arrived standard traffic when the node
    budget is contended."""
    sched = sim_sched(1.0, max_graphs=8, node_budget=25, deadline=10.0,
                      tiers=S.DEFAULT_TIERS)
    sched.submit(sized(0, 10), tenant="standard")
    sched.submit(sized(1, 10), tenant="standard")
    sched.submit(sized(2, 10), tenant="premium")   # contends -> full
    sched.drain()
    assert sched.launches[0]["req_ids"] == [2, 0]
    assert sched.launches[1]["req_ids"] == [1]


def test_backpressure_rejects_beyond_queue_depth():
    sched = sim_sched(1.0, max_graphs=8, deadline=10.0, depth=2)
    for i in range(5):
        sched.submit(P.make_graph(DS, i))
    sched.drain()
    s = sched.summary()
    assert s["served"] == 2
    assert s["rejected_queue_full"] == 3
    assert s["per_tenant"]["default"]["rejected"] == 3
    assert sorted(r.req_id for r in sched.responses) == list(range(5))


def test_oversize_fallback_vs_rejection():
    big = sized(0, 40)
    served = sim_sched(1.0, node_budget=20, allow_fallback=True)
    served.submit(big)
    served.drain()
    assert served.responses[0].status == S.SERVED_FALLBACK
    rejected = sim_sched(1.0, node_budget=20, allow_fallback=False)
    rejected.submit(big)
    assert rejected.responses[0].status == S.REJECTED_OVERSIZE


def test_oversize_head_does_not_starve_packed_work():
    """Head-of-order oversize waiting for the only fallback-capable lane
    (busy) must not block packed launches on the other lane."""
    cfg = S.SchedulerConfig(20, 1000, 1, default_tier=S.SLOTier("s", 10.0))
    lanes = [S.SimExecutor(S.constant_service(1.0), allow_fallback=True),
             S.SimExecutor(S.constant_service(1.0), allow_fallback=False)]
    sched = S.ContinuousScheduler(cfg, lanes)
    sched.submit(sized(0, 10))    # lane 0 busy (fallback-capable)
    sched.submit(sized(1, 40))    # oversize head, needs lane 0
    sched.submit(sized(2, 10))    # must ride lane 1 meanwhile
    assert [(l["req_ids"], l["executor"]) for l in sched.launches] \
        == [([0], 0), ([2], 1)]
    sched.drain()
    fb = next(r for r in sched.responses if r.req_id == 1)
    assert fb.status == S.SERVED_FALLBACK and fb.executor == 0


# -------------------------------------------------------------- stragglers --

def test_straggler_eviction_quarantines_slow_lane():
    """A lane 10x slower than its peer is flagged by the detector and
    quarantined (temporarily out of the pool, probe-back pending); its
    would-have-been work re-packs onto the healthy lane. The burst
    drains before the probe cooldown expires, so the lane is still
    quarantined at the end — probe-back itself is pinned in
    tests/test_faults.py."""
    sched = sim_sched(max_graphs=1, deadline=0.0, n_lanes=2,
                      service_per_lane=[0.01, 0.1])
    for i in range(40):
        sched.submit(P.make_graph(DS, i))
    sched.drain()
    s = sched.summary()
    assert s["quarantined_executors"] == [1]
    assert any(e["kind"] == "quarantine" and e["reason"] == "straggler"
               for e in sched.events)
    # the detector's state for the quarantined lane was cleared
    assert "exec1" not in sched.detector.hosts
    assert sorted(r.req_id for r in sched.responses) == list(range(40))
    slow = [l for l in sched.launches if l["executor"] == 1]
    assert 1 <= len(slow) <= 3, "slow lane quarantined after a few launches"
    last_seq = max(l["seq"] for l in slow)
    assert all(l["executor"] == 0 for l in sched.launches
               if l["seq"] > last_seq)


def test_last_lane_is_never_quarantined_for_slowness():
    sched = sim_sched(1.0, max_graphs=1, deadline=0.0)
    for i in range(20):
        sched.submit(P.make_graph(DS, i))
    sched.drain()
    assert sched.summary()["quarantined_executors"] == []
    assert len(sched.responses) == 20


def test_plan_executor_pool():
    assert S.plan_executor_pool(1) == 1
    assert S.plan_executor_pool(8) == 8
    assert S.plan_executor_pool(8, shards_per_executor=2) == 4
    assert S.plan_executor_pool(8, shards_per_executor=16) == 1


# ----------------------------------------------------- exactly-once sweeps --

def _exactly_once_body(seed: int, n: int, load: float, depth: int,
                       oversize_every: int, allow_fallback: bool):
    """Every submitted request gets exactly one Response, statuses
    partition, and oversize routes to fallback or explicit rejection."""
    node_budget = 64
    sched = sim_sched(0.01, max_graphs=4, node_budget=node_budget,
                      deadline=0.02, depth=depth,
                      allow_fallback=allow_fallback)
    trace = S.poisson_trace(n, load, DS, seed=seed,
                            tenants=(("premium", 0.2), ("standard", 0.5),
                                     ("batch", 0.3)))
    trace = [(t, dataclasses.replace(g, num_nodes=node_budget + 5)
              if i % oversize_every == 0 else g, tn)
             for i, (t, g, tn) in enumerate(trace)]
    S.run_trace(sched, trace)
    assert sorted(r.req_id for r in sched.responses) == list(range(n))
    s = sched.summary()
    assert s["served"] + s["rejected_queue_full"] \
        + s["rejected_oversize"] == n
    if not allow_fallback:
        assert s["fallback_served"] == 0
        oversize_ids = set(range(0, n, oversize_every))
        for r in sched.responses:
            if r.req_id in oversize_ids:
                assert r.status == S.REJECTED_OVERSIZE
    else:
        assert s["rejected_oversize"] == 0


def test_exactly_once_randomized_sweep():
    rng = np.random.default_rng(0)
    for seed in range(12):
        _exactly_once_body(seed, n=int(rng.integers(1, 60)),
                           load=float(rng.uniform(10, 400)),
                           depth=int(rng.integers(1, 8)),
                           oversize_every=int(rng.integers(2, 9)),
                           allow_fallback=bool(seed % 2))


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=hst.integers(0, 2**16), n=hst.integers(1, 50),
           load=hst.floats(1.0, 500.0), depth=hst.integers(1, 8),
           oversize_every=hst.integers(2, 10),
           allow_fallback=hst.booleans())
    def test_exactly_once_hypothesis(seed, n, load, depth,
                                     oversize_every, allow_fallback):
        _exactly_once_body(seed, n, load, depth, oversize_every,
                           allow_fallback)
else:
    @needs_hypothesis
    def test_exactly_once_hypothesis():
        pass  # covered by test_exactly_once_randomized_sweep above


def test_run_trace_sorts_unsorted_arrivals():
    """Regression: an out-of-order trace used to crash run_trace with
    the opaque "clock cannot run backwards" ValueError. It must now
    replay exactly like its time-sorted equivalent."""
    trace = [(t, P.make_graph(DS, i), "default")
             for i, t in enumerate([0.30, 0.10, 0.20, 0.05])]
    a = sim_sched(0.01, max_graphs=2, deadline=0.05)
    S.run_trace(a, trace)
    b = sim_sched(0.01, max_graphs=2, deadline=0.05)
    S.run_trace(b, sorted(trace, key=lambda p: p[0]))
    assert len(a.responses) == len(b.responses) == 4
    assert sorted((r.arrival_s, r.complete_s) for r in a.responses) \
        == sorted((r.arrival_s, r.complete_s) for r in b.responses)


def test_run_trace_rejects_prehistoric_and_nonfinite_arrivals():
    """An arrival before the scheduler's clock (or a non-finite one)
    raises an actionable error naming the offending trace entry."""
    sched = sim_sched(0.01)
    sched.clock.advance_to(5.0)
    with pytest.raises(ValueError, match=r"trace entry #1 .*t=1\.0"):
        S.run_trace(sched, [(6.0, P.make_graph(DS, 0), "default"),
                            (1.0, P.make_graph(DS, 1), "default")])
    with pytest.raises(ValueError, match="entry #0 has non-finite"):
        S.run_trace(sim_sched(0.01),
                    [(float("nan"), P.make_graph(DS, 0), "default")])


def test_poisson_trace_deterministic():
    a = S.poisson_trace(16, 100.0, DS, seed=7)
    b = S.poisson_trace(16, 100.0, DS, seed=7)
    assert [t for t, _, _ in a] == [t for t, _, _ in b]
    assert [tn for _, _, tn in a] == [tn for _, _, tn in b]
    c = S.poisson_trace(16, 100.0, DS, seed=8)
    assert [t for t, _, _ in a] != [t for t, _, _ in c]


# ------------------------------------------------- real-model parity (jax) --

def _small_model():
    import jax

    from repro.configs.gnn import DATASETS, config
    from repro.core import gnn_model as G
    from repro.nn import param as prm
    cfg = config("gcn", reduced=True)
    ds = DATASETS["qm9"]
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))
    fb = jax.jit(lambda p, el: G.apply(p, cfg, el))
    return ds, cfg, params, fn, fb


def _real_executor(params, fn, fb, service=0.01):
    import jax

    from repro.core import gnn_model as G

    def batch_fn(batch):
        return np.asarray(jax.block_until_ready(
            fn(params, G.packed_to_device(batch))))

    def fallback_fn(g):
        el = {"node_feat": np.asarray(g.node_feat),
              "edge_index": np.asarray(g.edge_index),
              "edge_feat": np.asarray(g.edge_feat),
              "num_nodes": np.int32(g.num_nodes)}
        return np.asarray(jax.block_until_ready(fb(params, el)))

    return S.SimExecutor(S.constant_service(service), batch_fn=batch_fn,
                         fallback_fn=fallback_fn)


def test_output_parity_with_offline_apply_packed():
    """Bitwise: each launch's outputs equal re-running the identical
    batch composition offline through apply_packed (and each fallback
    equals the padded per-graph oracle)."""
    from repro.core import gnn_model as G
    from repro.data import pipeline as P2

    ds, cfg, params, fn, fb = _small_model()
    nb = P2.size_budget(4, ds.avg_nodes)
    eb = P2.size_budget(4, ds.avg_nodes * ds.avg_degree)
    scfg = S.SchedulerConfig(nb, eb, 4,
                             default_tier=S.SLOTier("s", 0.02, 1))
    sched = S.ContinuousScheduler(scfg, _real_executor(params, fn, fb))
    trace = S.poisson_trace(20, 300.0, ds, seed=1)
    # force one fallback launch into the mix
    t, g, tn = trace[7]
    trace[7] = (t, dataclasses.replace(g, num_nodes=nb + 1), tn)
    S.run_trace(sched, trace)
    gmap = {i: g for i, (_, g, _) in enumerate(trace)}
    out = {r.req_id: r for r in sched.responses}
    assert sorted(out) == list(range(20))
    for launch in sched.launches:
        if launch["kind"] == "packed":
            batch, k = P2.pack_graphs([gmap[r] for r in launch["req_ids"]],
                                      nb, eb, 4)
            assert k == len(launch["req_ids"])
            import jax
            ref = np.asarray(jax.block_until_ready(
                fn(params, G.packed_to_device(batch))))
            for j, rid in enumerate(launch["req_ids"]):
                assert np.array_equal(ref[j], out[rid].output)
        else:
            (rid,) = launch["req_ids"]
            assert out[rid].status == S.SERVED_FALLBACK


def test_packing_order_invariance():
    """The same six graphs submitted in opposite orders land in one
    batch each; every graph's output matches across the two pack
    orders."""
    ds, cfg, params, fn, fb = _small_model()
    from repro.data import pipeline as P2
    nb = P2.size_budget(8, ds.avg_nodes)
    eb = P2.size_budget(8, ds.avg_nodes * ds.avg_degree)
    graphs = [P2.make_graph(ds, i) for i in range(6)]

    def run(order):
        scfg = S.SchedulerConfig(nb, eb, 8,
                                 default_tier=S.SLOTier("s", 10.0, 1))
        sched = S.ContinuousScheduler(scfg,
                                      _real_executor(params, fn, fb))
        for g in order:
            sched.submit(g)
        sched.drain()
        assert len(sched.launches) == 1
        return {id(order[r.req_id]): r.output for r in sched.responses}

    fwd = run(graphs)
    rev = run(list(reversed(graphs)))
    for g in graphs:
        np.testing.assert_allclose(fwd[id(g)], rev[id(g)],
                                   rtol=1e-5, atol=1e-5)


# --------------------------------------------- continuous vs wave baseline --

def test_continuous_beats_wave_p99():
    """At a load where the wave window takes much longer to fill than
    the deadline, continuous batching must cut p99 without losing
    requests."""
    cfg = S.SchedulerConfig(1000, 1000, 8,
                            default_tier=S.SLOTier("s", 0.02, 1))
    trace = S.poisson_trace(64, 100.0, DS, seed=2)

    def executor():
        return S.SimExecutor(S.constant_service(0.005))

    sched = S.ContinuousScheduler(cfg, executor())
    S.run_trace(sched, trace)
    cs = sched.summary()
    _, ws = S.simulate_wave_drain(trace, cfg, executor())
    assert cs["served"] == ws["served"] == 64
    assert cs["p99_latency_s"] < ws["p99_latency_s"]
    assert cs["p50_latency_s"] < ws["p50_latency_s"]
