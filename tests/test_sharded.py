"""Data-parallel sharded packed inference: shard_pack partitioning
invariants, sharded-vs-single-device parity over the registry-derived
conv x precision x backend grid (tests/parity.py) on simulated host
devices, host-order gather, uneven shard counts, and the num_shards
DSE/feature plumbing."""
import numpy as np
import pytest

import parity
from repro.core import dse
from repro.core import perf_model as PM
from repro.data import pipeline as P

DS = P.GraphDataConfig(avg_nodes=10, max_nodes=64, max_edges=64,
                       node_feat_dim=7, edge_feat_dim=3, seed=5)


def _graphs(n=10):
    return [P.make_graph(DS, i) for i in range(n)]


# ----------------------------------------------------- shard_pack -------
def test_shard_pack_partitions_and_respects_budgets():
    graphs = _graphs(12)
    wave, k = P.shard_pack(graphs, 96, 192, 8, num_shards=3)
    assert k == wave.n_graphs
    seen = sorted(pos for ix in wave.index for pos in ix)
    assert seen == list(range(k))            # consumed prefix, exactly once
    for shard, ix in zip(wave.shards, wave.index):
        assert int(shard["num_graphs"]) == len(ix)
        assert int((shard["node_graph_id"] < 8).sum()) <= 96
        assert int((shard["edge_index"][:, 0] >= 0).sum()) <= 192
        # shard-internal order follows the stream
        assert ix == sorted(ix)


def test_shard_pack_balances_least_loaded():
    """Equal-size graphs round-robin across shards instead of filling
    shard 0 first."""
    graphs = _graphs(8)
    wave, k = P.shard_pack(graphs, 10_000, 10_000, 8, num_shards=4)
    assert k == 8
    per_shard = [len(ix) for ix in wave.index]
    assert max(per_shard) - min(per_shard) <= 1, per_shard


def test_shard_pack_empty_shard_keeps_shapes():
    """More shards than graphs: idle shards carry the all-padding batch
    with identical static shapes (every mesh device needs a block)."""
    graphs = _graphs(2)
    wave, k = P.shard_pack(graphs, 96, 192, 4, num_shards=4)
    assert k == 2 and wave.num_shards == 4
    empties = [s for s, ix in enumerate(wave.index) if not ix]
    assert len(empties) == 2
    ref = wave.shards[0]
    for s in empties:
        b = wave.shards[s]
        assert int(b["num_graphs"]) == 0
        assert not b["graph_valid"].any()
        assert (b["node_graph_id"] == 4).all()
        assert (b["edge_index"] == -1).all()
        for key in ref:
            assert b[key].shape == ref[key].shape, key


def test_shard_pack_raises_on_oversize_first():
    with pytest.raises(ValueError):
        P.shard_pack(_graphs(3), node_budget=2, edge_budget=2,
                     max_graphs=4, num_shards=2)
    with pytest.raises(ValueError):
        P.shard_pack(_graphs(3), 96, 192, 4, num_shards=0)


def test_empty_graph_batch_matches_packed_layout():
    b = P.empty_graph_batch(32, 48, 4, DS.node_feat_dim, DS.edge_feat_dim)
    packed, _ = P.pack_graphs(_graphs(1), 32, 48, 4)
    assert set(b) == set(packed)
    for k in b:
        assert b[k].shape == packed[k].shape, k
        assert b[k].dtype == packed[k].dtype, k


# ------------------------------------------- pack_dataset(num_shards=) --
def test_pack_dataset_sharded_covers_stream_in_order():
    graphs = _graphs(24)
    waves, dropped = P.pack_dataset(graphs, 48, 96, 4, num_shards=2)
    assert not dropped
    assert all(isinstance(w, P.ShardedBatch) for w in waves)
    total = sum(w.n_graphs for w in waves)
    assert total == len(graphs)
    # gather per wave, concatenate: ids visit the stream in order
    pos = 0
    for w in waves:
        marks = np.zeros((w.n_graphs, 1), np.float32)
        outs = np.zeros((w.num_shards, 4, 1), np.float32)
        for s, ix in enumerate(w.index):
            for j, p_ in enumerate(ix):
                outs[s, j, 0] = pos + p_
        marks = P.gather_shard_outputs(outs, w.index)
        np.testing.assert_array_equal(
            marks[:, 0], np.arange(pos, pos + w.n_graphs))
        pos += w.n_graphs


def test_pack_dataset_sharded_drops_only_oversize():
    graphs = _graphs(6)
    big = P.make_graph(P.GraphDataConfig(avg_nodes=40, max_nodes=64,
                                         max_edges=64, node_feat_dim=7,
                                         edge_feat_dim=3, seed=1), 0)
    waves, dropped = P.pack_dataset(graphs[:3] + [big] + graphs[3:],
                                    24, 96, 4, num_shards=2)
    assert dropped == [big]
    assert sum(w.n_graphs for w in waves) == 6


def test_pack_dataset_single_shard_unchanged():
    """num_shards=1 keeps the original (batches, dropped) contract."""
    graphs = _graphs(8)
    batches, dropped = P.pack_dataset(graphs, 96, 192, 4)
    assert all(isinstance(b, dict) for b in batches)
    assert sum(int(b["num_graphs"]) for b in batches) + len(dropped) \
        == len(graphs)


# ------------------------------------------------ gather host order -----
def test_gather_shard_outputs_inverts_index():
    outs = np.arange(2 * 3 * 2, dtype=np.float32).reshape(2, 3, 2)
    index = [[0, 3], [1, 2]]            # shard 0 -> rows 0,3; shard 1 -> 1,2
    host = P.gather_shard_outputs(outs, index)
    np.testing.assert_array_equal(host[0], outs[0, 0])
    np.testing.assert_array_equal(host[3], outs[0, 1])
    np.testing.assert_array_equal(host[1], outs[1, 0])
    np.testing.assert_array_equal(host[2], outs[1, 1])


# ------------------------------------------------- DSE / feature axis ---
def test_space_has_num_shards_and_features_roundtrip():
    rng = np.random.default_rng(0)
    assert 1 in dse.SPACE["num_shards"]
    d = dse.sample_design(rng)
    assert d["num_shards"] in dse.SPACE["num_shards"]
    v = PM.features(d)
    assert len(v) == len(PM.FEATURE_NAMES)
    hot = [v[PM.FEATURE_NAMES.index(f"shards_{n}")] for n in (2, 4, 8)]
    assert sum(hot) == (0.0 if d["num_shards"] == 1 else 1.0)
    if d["num_shards"] > 1:
        assert hot[(2, 4, 8).index(d["num_shards"])] == 1.0


def test_legacy_design_featurizes_as_single_device():
    """Databases recorded before the sharding axis still featurize:
    num_shards defaults to 1 (zero one-hot)."""
    rng = np.random.default_rng(1)
    d = dse.sample_design(rng)
    d.pop("num_shards", None)
    v = PM.features(d)
    assert len(v) == len(PM.FEATURE_NAMES)
    for n in (2, 4, 8):
        assert v[PM.FEATURE_NAMES.index(f"shards_{n}")] == 0.0


# --------------------------------------- sharded parity (fake devices) --
# The device count must be pinned before jax initializes, so the parity
# grid runs in one subprocess over 2 simulated host devices: every
# registered conv, every precision its ConvSpec declares, both
# aggregation backends, plus an uneven wave (9 graphs over 2 shards)
# and a 4-shard wave with idle shards. Host order is checked against
# the padded per-graph oracle. The grid body lives in tests/parity.py
# next to the packed and partitioned cells of the same matrix.
@pytest.mark.budget(840)
def test_sharded_parity_grid_subprocess():
    parity.run_parity_subprocess(parity.sharded_parity_script(),
                                 "SHARDED_PARITY_OK")
