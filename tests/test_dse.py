"""DSE robustness: stable artifact naming, fail-soft exploration, and
batch-budget throughput models."""
import numpy as np

from repro.core import dse


def _db(n=10, seed=0, hbm=None, with_throughput=True):
    rng = np.random.default_rng(seed)
    db = []
    for _ in range(n):
        d = dse.sample_design(rng)
        d["latency_s"] = float(rng.uniform(1e-5, 1e-3))
        d["hbm_bytes"] = float(hbm if hbm is not None
                               else rng.uniform(1e6, 1e9))
        if with_throughput:
            d["graphs_per_s"] = float(rng.uniform(1e3, 1e6))
        db.append(d)
    return db


def test_design_name_stable_and_order_independent():
    rng = np.random.default_rng(2)
    d = dse.sample_design(rng)
    name1 = dse.design_name(d)
    name2 = dse.design_name(dict(reversed(list(d.items()))))
    assert name1 == name2            # insertion order must not matter
    assert name1.startswith("dse_") and len(name1) == len("dse_") + 12
    d2 = dict(d, gnn_hidden_dim=d["gnn_hidden_dim"] + 1)
    assert dse.design_name(d2) != name1


def test_explore_feasible_flag_true_under_loose_budget():
    models = dse.fit_models(_db())
    best = dse.explore(models, n_candidates=64, seed=1,
                       memory_budget=1e18)
    assert best["feasible"] is True
    assert best["pred_latency_s"] > 0
    assert "pred_graphs_per_s" in best      # throughput model fitted


def test_explore_fails_soft_when_nothing_fits():
    # every training point uses ~1e9 bytes, so predictions never fit 1 B
    models = dse.fit_models(_db(hbm=1e9))
    best = dse.explore(models, n_candidates=64, seed=1, memory_budget=1.0)
    assert best["feasible"] is False
    assert best["memory_violation_bytes"] > 0
    assert best["pred_latency_s"] > 0       # still the best-latency design


def test_fit_models_without_throughput_key():
    models = dse.fit_models(_db(with_throughput=False))
    assert models.throughput is None
    best = dse.explore(models, n_candidates=32, seed=2,
                       memory_budget=1e18)
    assert "pred_graphs_per_s" not in best


def test_sampled_designs_carry_batch_budgets():
    rng = np.random.default_rng(4)
    d = dse.sample_design(rng)
    assert d["batch_graphs"] in dse.SPACE["batch_graphs"]
    assert d["node_budget"] >= d["batch_graphs"] * d["avg_nodes"]
    assert d["edge_budget"] >= d["batch_graphs"] * d["avg_edges"]


def test_sampled_designs_carry_kernel_tiles():
    """edge_block/node_block (segment-aggregation tile sizes) are design
    axes: sampled, featurized, and returned by explore with the
    feasibility flag intact."""
    rng = np.random.default_rng(5)
    ds = [dse.sample_design(rng) for _ in range(64)]
    assert all(d["edge_block"] in dse.SPACE["edge_block"] for d in ds)
    assert all(d["node_block"] in dse.SPACE["node_block"] for d in ds)
    assert len({d["edge_block"] for d in ds}) > 1      # actually sampled
    models = dse.fit_models(_db())
    best = dse.explore(models, n_candidates=64, seed=3,
                       memory_budget=1e18)
    assert best["feasible"] is True
    assert best["edge_block"] in dse.SPACE["edge_block"]
    assert best["node_block"] in dse.SPACE["node_block"]


def test_tile_knobs_move_synthesis_objective(tmp_path):
    """edge_block/node_block must not be inert DSE axes: the packed
    synthesis report charges per-grid-step overhead, so smaller tiles
    mean more steps and strictly higher modeled packed latency."""
    from repro.core import gnn_model as G
    from repro.core.project import Project

    def report(eb, nb):
        cfg = G.GNNModelConfig(
            graph_input_feature_dim=4, graph_input_edge_dim=0,
            gnn_hidden_dim=8, gnn_num_layers=2, gnn_output_dim=8,
            mlp_head=G.MLPConfig(in_dim=24, out_dim=1, hidden_dim=8,
                                 hidden_layers=1))
        proj = Project(f"tiles_{eb}_{nb}", cfg, "dse", str(tmp_path),
                       max_nodes=64, max_edges=64, batch_graphs=8,
                       edge_block=eb, node_block=nb)
        proj.gen_hw_model()
        return proj.run_synthesis()["packed"]

    small = report(64, 32)
    large = report(256, 128)
    assert small["agg_grid_steps"] > large["agg_grid_steps"]
    assert small["agg_overhead_s"] > large["agg_overhead_s"]
    assert small["latency_s"] > large["latency_s"]
    assert small["graphs_per_s"] < large["graphs_per_s"]
    assert small["edge_block"] == 64 and small["node_block"] == 32


def test_features_default_tiles_for_old_databases():
    """Databases recorded before the tile knobs existed still featurize
    (defaults 128/128), and the vector length matches FEATURE_NAMES."""
    from repro.core import perf_model as PM
    rng = np.random.default_rng(6)
    d = dse.sample_design(rng)
    d.pop("edge_block")
    d.pop("node_block")
    v = PM.features(d)
    assert len(v) == len(PM.FEATURE_NAMES)
    assert v[PM.FEATURE_NAMES.index("edge_block")] == 128
    assert v[PM.FEATURE_NAMES.index("node_block")] == 128


def test_explore_p99_latency_objective():
    """The SLO-aware objective simulates top candidates through the
    continuous scheduler and reports traffic-shaped percentiles."""
    models = dse.fit_models(_db())
    slo = {"load_graphs_per_s": 512.0, "deadline_s": 0.02,
           "n_requests": 48, "top_k": 4}
    best = dse.explore(models, n_candidates=32, seed=1,
                       memory_budget=1e18, objective="p99_latency",
                       slo=slo)
    assert best["feasible"] is True
    assert best["objective"] == "p99_latency"
    assert best["pred_p99_latency_s"] >= best["pred_p50_latency_s"] > 0
    assert 0 < best["pred_batch_fill"] <= 1.0
    assert best["pred_rejected"] == 0
    assert best["slo"]["n_requests"] == 48


def test_explore_rejects_unknown_objective():
    import pytest
    models = dse.fit_models(_db())
    with pytest.raises(ValueError):
        dse.explore(models, n_candidates=8, seed=1,
                    memory_budget=1e18, objective="p42")
