"""DSE robustness: stable artifact naming, fail-soft exploration, and
batch-budget throughput models."""
import numpy as np

from repro.core import dse


def _db(n=10, seed=0, hbm=None, with_throughput=True):
    rng = np.random.default_rng(seed)
    db = []
    for _ in range(n):
        d = dse.sample_design(rng)
        d["latency_s"] = float(rng.uniform(1e-5, 1e-3))
        d["hbm_bytes"] = float(hbm if hbm is not None
                               else rng.uniform(1e6, 1e9))
        if with_throughput:
            d["graphs_per_s"] = float(rng.uniform(1e3, 1e6))
        db.append(d)
    return db


def test_design_name_stable_and_order_independent():
    rng = np.random.default_rng(2)
    d = dse.sample_design(rng)
    name1 = dse.design_name(d)
    name2 = dse.design_name(dict(reversed(list(d.items()))))
    assert name1 == name2            # insertion order must not matter
    assert name1.startswith("dse_") and len(name1) == len("dse_") + 12
    d2 = dict(d, gnn_hidden_dim=d["gnn_hidden_dim"] + 1)
    assert dse.design_name(d2) != name1


def test_explore_feasible_flag_true_under_loose_budget():
    models = dse.fit_models(_db())
    best = dse.explore(models, n_candidates=64, seed=1,
                       memory_budget=1e18)
    assert best["feasible"] is True
    assert best["pred_latency_s"] > 0
    assert "pred_graphs_per_s" in best      # throughput model fitted


def test_explore_fails_soft_when_nothing_fits():
    # every training point uses ~1e9 bytes, so predictions never fit 1 B
    models = dse.fit_models(_db(hbm=1e9))
    best = dse.explore(models, n_candidates=64, seed=1, memory_budget=1.0)
    assert best["feasible"] is False
    assert best["memory_violation_bytes"] > 0
    assert best["pred_latency_s"] > 0       # still the best-latency design


def test_fit_models_without_throughput_key():
    models = dse.fit_models(_db(with_throughput=False))
    assert models.throughput is None
    best = dse.explore(models, n_candidates=32, seed=2,
                       memory_budget=1e18)
    assert "pred_graphs_per_s" not in best


def test_sampled_designs_carry_batch_budgets():
    rng = np.random.default_rng(4)
    d = dse.sample_design(rng)
    assert d["batch_graphs"] in dse.SPACE["batch_graphs"]
    assert d["node_budget"] >= d["batch_graphs"] * d["avg_nodes"]
    assert d["edge_budget"] >= d["batch_graphs"] * d["avg_edges"]
