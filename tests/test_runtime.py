"""Fault-tolerance runtime: straggler detection, elastic planning, and the
trainer's fail -> restart -> exact-resume path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.elastic import plan_mesh_shape
from repro.runtime.straggler import StragglerDetector
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig


def test_straggler_flags_slow_host():
    det = StragglerDetector(threshold=1.5, evict_after=2)
    for _ in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            det.record(h, 1.0)
        det.record("h4", 3.0)
    a1 = det.check()
    assert a1 == {"h4": "reshard_input"}
    a2 = det.check()
    assert a2 == {"h4": "evict"}


def test_straggler_recovers():
    det = StragglerDetector(threshold=1.5, evict_after=3, decay=0.5)
    for h in ("h0", "h1", "h2"):
        det.record(h, 1.0)
    det.record("h3", 5.0)
    assert "h3" in det.check()
    for _ in range(8):
        det.record("h3", 1.0)
    assert det.check() == {}


@pytest.mark.parametrize("n,model,want", [
    (512, 16, ((32, 16), ("data", "model"))),
    (496, 16, ((31, 16), ("data", "model"))),    # lost a host of 16
    (250, 16, ((125, 2), ("data", "model"))),
    (7, 16, ((7, 1), ("data", "model"))),
])
def test_plan_mesh_shape(n, model, want):
    assert plan_mesh_shape(n, model) == want


# ---------------------------------------------------------------- trainer --
def _toy_setup(tmp_path, fail_at=None, total=30):
    target = jnp.arange(4.0)

    def step_fn(params, opt, batch):
        g = 2 * (params["w"] - target) + batch["noise"]
        params = {"w": params["w"] - 0.05 * g}
        loss = jnp.sum((params["w"] - target) ** 2)
        return params, opt, {"loss": loss}

    def batch_fn(step):
        rng = np.random.default_rng(step)   # pure function of step
        return {"noise": jnp.asarray(rng.standard_normal(4) * 0.01,
                                     jnp.float32)}

    cfg = TrainerConfig(total_steps=total, ckpt_every=10,
                        ckpt_dir=str(tmp_path), log_every=1000)
    return Trainer(cfg, step_fn, batch_fn, {"w": jnp.zeros(4)}, {},
                   fail_at_step=fail_at, log=None)


def test_trainer_runs_and_checkpoints(tmp_path):
    t = _toy_setup(tmp_path)
    res = t.run()
    assert res["final_step"] == 30
    assert t.ckpt.latest_step() == 30
    assert res["losses"][-1] < res["losses"][0]


def test_trainer_fail_restart_resume_exact(tmp_path):
    """Crash at step 17, restart, and verify the final state is bitwise
    identical to an uninterrupted run (pure step->batch + checkpointing)."""
    ref = _toy_setup(tmp_path / "ref")
    ref_res = ref.run()

    t1 = _toy_setup(tmp_path / "ft", fail_at=17)
    with pytest.raises(SimulatedFailure):
        t1.run()
    # "new process": fresh trainer, same dirs -> resumes from step 10
    t2 = _toy_setup(tmp_path / "ft")
    t2.params = {"w": jnp.zeros(4)}
    res = t2.run()
    assert res["final_step"] == 30
    np.testing.assert_array_equal(np.asarray(t2.params["w"]),
                                  np.asarray(ref.params["w"]))
