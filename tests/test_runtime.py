"""Fault-tolerance runtime: straggler detection, elastic planning, and the
trainer's fail -> restart -> exact-resume path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.elastic import plan_mesh_shape
from repro.runtime.straggler import StragglerDetector
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig


def test_straggler_flags_slow_host():
    det = StragglerDetector(threshold=1.5, evict_after=2)
    for _ in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            det.record(h, 1.0)
        det.record("h4", 3.0)
    a1 = det.check()
    assert a1 == {"h4": "reshard_input"}
    a2 = det.check()
    assert a2 == {"h4": "evict"}


def test_straggler_recovers():
    det = StragglerDetector(threshold=1.5, evict_after=3, decay=0.5)
    for h in ("h0", "h1", "h2"):
        det.record(h, 1.0)
    det.record("h3", 5.0)
    assert "h3" in det.check()
    for _ in range(8):
        det.record("h3", 1.0)
    assert det.check() == {}


def test_straggler_threshold_is_strict():
    """A host sitting exactly at threshold x median is healthy; only
    strictly above is flagged."""
    det = StragglerDetector(threshold=1.5)
    for h, v in (("h0", 1.0), ("h1", 1.0), ("h2", 1.5)):
        det.record(h, v)                    # first sample -> ema = v
    assert det.median_ema() == 1.0
    assert det.check() == {}                # 1.5 == 1.5 * median: healthy
    det2 = StragglerDetector(threshold=1.5)
    for h, v in (("h0", 1.0), ("h1", 1.0), ("h2", 1.5 + 1e-9)):
        det2.record(h, v)
    assert det2.check() == {"h2": "reshard_input"}


def test_straggler_decay_edges():
    frozen = StragglerDetector(decay=1.0)   # ema pinned to first sample
    frozen.record("h", 1.0)
    for _ in range(5):
        frozen.record("h", 100.0)
    assert frozen.hosts["h"].ema == 1.0
    latest = StragglerDetector(decay=0.0)   # ema tracks latest sample
    latest.record("h", 1.0)
    latest.record("h", 7.0)
    assert latest.hosts["h"].ema == 7.0


def test_straggler_empty_check():
    assert StragglerDetector().check() == {}


def test_straggler_forget_clears_quarantined_host():
    """Pinned behavior for lane quarantine/retirement (the serving
    scheduler calls ``forget`` when it quarantines a lane): without it,
    ``record`` keeps accumulating for the gone host and ``check()``
    keeps re-flagging it on stale EMAs forever."""
    det = StragglerDetector(threshold=1.5, evict_after=2)
    for _ in range(4):
        for h in ("h0", "h1", "h2"):
            det.record(h, 1.0)
        det.record("slow", 5.0)
    det.check()
    assert det.check()["slow"] == "evict"
    det.forget("slow")
    assert "slow" not in det.hosts
    assert det.check() == {}, "a forgotten host must not be re-flagged"
    # the host's median contribution is gone too
    assert det.median_ema() == 1.0
    # re-admission (probe-back) starts from a fresh first sample
    det.record("slow", 1.0)
    assert det.hosts["slow"].ema == 1.0
    assert det.hosts["slow"].flagged_streak == 0
    det.forget("never-seen")            # forgetting the unknown is a no-op


def test_pool_plan_rides_mesh_planning():
    from repro.runtime.elastic import pool_plan
    assert pool_plan(4) == {"n_lanes": 4, "mesh_shape": (4, 1),
                            "axes": ("data", "model")}
    assert pool_plan(3, shards_per_executor=2) \
        == {"n_lanes": 3, "mesh_shape": (3, 2),
            "axes": ("data", "model")}
    with pytest.raises(ValueError):
        pool_plan(0)


@pytest.mark.parametrize("n,model,want", [
    (512, 16, ((32, 16), ("data", "model"))),
    (496, 16, ((31, 16), ("data", "model"))),    # lost a host of 16
    (250, 16, ((125, 2), ("data", "model"))),
    (7, 16, ((7, 1), ("data", "model"))),
    (1, 16, ((1, 1), ("data", "model"))),        # single survivor
])
def test_plan_mesh_shape(n, model, want):
    assert plan_mesh_shape(n, model) == want


@pytest.mark.parametrize("n", [0, -3])
def test_plan_mesh_shape_rejects_empty(n):
    with pytest.raises(ValueError):
        plan_mesh_shape(n)


def test_plan_mesh_shape_pod_axis():
    assert plan_mesh_shape(512, 16, pod=4) \
        == ((4, 8, 16), ("pod", "data", "model"))
    # pod not dividing the data axis falls back to the 2-axis grid
    assert plan_mesh_shape(512, 16, pod=3) \
        == ((32, 16), ("data", "model"))


def test_replan_single_device():
    from repro.runtime.elastic import replan
    mesh = replan(jax.devices()[:1])
    assert mesh.devices.shape == (1, 1)
    assert mesh.axis_names == ("data", "model")


# ---------------------------------------------------------------- trainer --
def _toy_setup(tmp_path, fail_at=None, total=30):
    target = jnp.arange(4.0)

    def step_fn(params, opt, batch):
        g = 2 * (params["w"] - target) + batch["noise"]
        params = {"w": params["w"] - 0.05 * g}
        loss = jnp.sum((params["w"] - target) ** 2)
        return params, opt, {"loss": loss}

    def batch_fn(step):
        rng = np.random.default_rng(step)   # pure function of step
        return {"noise": jnp.asarray(rng.standard_normal(4) * 0.01,
                                     jnp.float32)}

    cfg = TrainerConfig(total_steps=total, ckpt_every=10,
                        ckpt_dir=str(tmp_path), log_every=1000)
    return Trainer(cfg, step_fn, batch_fn, {"w": jnp.zeros(4)}, {},
                   fail_at_step=fail_at, log=None)


def test_trainer_runs_and_checkpoints(tmp_path):
    t = _toy_setup(tmp_path)
    res = t.run()
    assert res["final_step"] == 30
    assert t.ckpt.latest_step() == 30
    assert res["losses"][-1] < res["losses"][0]


def test_trainer_fail_restart_resume_exact(tmp_path):
    """Crash at step 17, restart, and verify the final state is bitwise
    identical to an uninterrupted run (pure step->batch + checkpointing)."""
    ref = _toy_setup(tmp_path / "ref")
    ref_res = ref.run()

    t1 = _toy_setup(tmp_path / "ft", fail_at=17)
    with pytest.raises(SimulatedFailure):
        t1.run()
    # "new process": fresh trainer, same dirs -> resumes from step 10
    t2 = _toy_setup(tmp_path / "ft")
    t2.params = {"w": jnp.zeros(4)}
    res = t2.run()
    assert res["final_step"] == 30
    np.testing.assert_array_equal(np.asarray(t2.params["w"]),
                                  np.asarray(ref.params["w"]))
