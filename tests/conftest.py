"""Shared pytest configuration: the test-time budget check.

CI runs tier-1 with ``PYTEST_TEST_BUDGET_S=60``: any test whose call
phase runs longer than the budget fails the session with a listed
offender, so slow tests are caught the day they land instead of when
the suite becomes unbearable. Locally (no env var) the check is off
and the driver's plain ``pytest -x -q`` behaves exactly as before.
Tests with a legitimate reason to run long — the subprocess parity
grids compile a full conv x precision x backend matrix twice — declare
their own ceiling with ``@pytest.mark.budget(seconds)``.
"""
import os

import pytest

_BUDGET_ENV = "PYTEST_TEST_BUDGET_S"
_violations = []


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "budget(seconds): per-test wall-clock ceiling overriding the "
        f"{_BUDGET_ENV} default for tests that legitimately run long")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    yield
    default = os.environ.get(_BUDGET_ENV)
    if default is None or call.when != "call":
        return
    budget = float(default)
    mark = item.get_closest_marker("budget")
    if mark is not None:
        budget = float(mark.args[0])
    if call.duration > budget:
        _violations.append((item.nodeid, call.duration, budget))


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if not _violations:
        return
    terminalreporter.section("test-time budget violations")
    for nodeid, duration, budget in _violations:
        terminalreporter.write_line(
            f"{nodeid}: {duration:.1f}s > {budget:.0f}s budget")
    terminalreporter.write_line(
        f"(raise a test's own ceiling with @pytest.mark.budget(seconds) "
        f"or adjust {_BUDGET_ENV})")


def pytest_sessionfinish(session, exitstatus):
    if _violations and exitstatus == 0:
        session.exitstatus = 1
