"""Fused gather->phi->aggregate pipeline: kernel == ref == materialized
XLA across aggregations/shapes/scales, fused-vs-materialized parity for
every registered conv x precision on packed batches (empty graphs,
all-padding edge blocks, isolated nodes) via the shared tests/parity.py
matrix, dataflow planner resolution and override combinations, and the
serve-path oversize fallback."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import parity
from repro.core import aggregations as A
from repro.core import convs as C
from repro.core import gnn_model as G
from repro.core.aggregations import GATHER_AGGREGATIONS
from repro.data import pipeline as P
from repro.kernels.fused_gather_aggregate.ops import fused_gather_aggregate
from repro.kernels.fused_gather_aggregate.ref import (
    fused_gather_aggregate_ref)
from repro.nn import param as prm

DS = P.GraphDataConfig(avg_nodes=10, max_nodes=64, max_edges=64,
                       node_feat_dim=11, edge_feat_dim=4, seed=5)


def _cfg(conv, dataflow="auto", hidden=16, out=8, task="graph"):
    return G.GNNModelConfig(
        graph_input_feature_dim=11, graph_input_edge_dim=4,
        gnn_hidden_dim=hidden, gnn_num_layers=2, gnn_output_dim=out,
        gnn_conv=conv, gnn_dataflow=dataflow, task=task,
        mlp_head=G.MLPConfig(in_dim=out * 3, out_dim=1, hidden_dim=8,
                             hidden_layers=1) if task == "graph" else None)


def _empty_edge_graph(n=3):
    nf = np.zeros((DS.max_nodes, DS.node_feat_dim), np.float32)
    nf[:n] = np.random.default_rng(7).standard_normal(
        (n, DS.node_feat_dim))
    return P.Graph(node_feat=nf,
                   edge_index=np.full((DS.max_edges, 2), -1, np.int32),
                   edge_feat=np.zeros((DS.max_edges, DS.edge_feat_dim),
                                      np.float32),
                   num_nodes=n, num_edges=0,
                   y=np.zeros((1,), np.float32))


def _packed_batch():
    """5 synthetic graphs + one zero-edge graph (isolated nodes) packed
    into a 128-node/256-edge buffer: the tail edge blocks of the packed
    stream are pure padding."""
    gs = [P.make_graph(DS, i) for i in range(5)]
    gs.insert(2, _empty_edge_graph())
    batch, k = P.pack_graphs(gs, 128, 256, 8)
    assert k == len(gs)
    return gs, {kk: jnp.asarray(v) for kk, v in batch.items() if kk != "y"}


def _stream(n=37, e=91, f=5, seed=0, pad_every=7):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    if pad_every:
        src[::pad_every] = -1
        dst[::pad_every] = -1
    scale = jnp.asarray(rng.uniform(0.5, 2.0, e), jnp.float32)
    return x, jnp.asarray(src), jnp.asarray(dst), scale


# ------------------------------------------------- kernel-level parity --
@pytest.mark.parametrize("agg", GATHER_AGGREGATIONS)
@pytest.mark.parametrize("with_scale", [False, True])
def test_kernel_matches_ref_and_materialized(agg, with_scale):
    """Fused kernel == pure-jnp mirror == gather-then-segment XLA, on a
    non-divisible shape with interleaved padding edges."""
    x, src, dst, scale = _stream()
    sc = scale if with_scale else None
    got = np.asarray(fused_gather_aggregate(
        x, src, dst, None, sc, num_segments=37, agg=agg,
        edge_block=16, node_block=8))
    ref = np.asarray(fused_gather_aggregate_ref(
        x, src, dst, 37, scale=sc, agg=agg))
    xla = np.asarray(A.gather_aggregate(
        agg, x, src, dst, 37, scale=sc, backend="xla"))
    np.testing.assert_allclose(got, ref, atol=1e-5)
    np.testing.assert_allclose(got, xla, atol=1e-5)


@pytest.mark.parametrize("agg", GATHER_AGGREGATIONS)
def test_kernel_all_padding_edge_blocks(agg):
    """Edge blocks made entirely of padding contribute nothing, and
    zero-in-degree nodes zero-fill."""
    x, src, dst, _ = _stream(n=12, e=64, f=3, pad_every=0)
    src = np.asarray(src).copy()
    dst = np.asarray(dst).copy()
    src[16:] = -1            # blocks 2..4 of edge_block=16: all padding
    dst[16:] = -1
    dst[:16] = np.arange(16) % 5         # nodes 5..11 isolated
    got = np.asarray(fused_gather_aggregate(
        x, jnp.asarray(src), jnp.asarray(dst), num_segments=12, agg=agg,
        edge_block=16, node_block=8))
    xla = np.asarray(A.gather_aggregate(
        agg, x, jnp.asarray(src), jnp.asarray(dst), 12, backend="xla"))
    np.testing.assert_allclose(got, xla, atol=1e-5)
    np.testing.assert_allclose(got[5:], 0.0, atol=1e-6)


def test_kernel_empty_stream_and_valid_mask():
    x, src, dst, scale = _stream(e=24, pad_every=0)
    z = np.asarray(fused_gather_aggregate(
        x, jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
        num_segments=37, agg="sum"))
    assert z.shape == (37, 5) and np.abs(z).max() == 0.0
    # valid=False edges are dropped exactly like -1 ids
    valid = jnp.asarray(np.arange(24) % 3 != 0)
    got = np.asarray(fused_gather_aggregate(
        x, src, dst, valid, scale, num_segments=37, agg="sum"))
    src2 = jnp.where(valid, src, -1)
    want = np.asarray(A.gather_aggregate(
        "sum", x, src2, dst, 37, scale=scale, backend="xla"))
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("agg", GATHER_AGGREGATIONS)
def test_backends_agree_on_out_of_range_ids(agg):
    """Ids outside the valid range on *either* stream — high (the packed
    overflow bucket) or -1 — are padding under both backends: no NaN
    fill rows from the gather, identical outputs."""
    x, _, _, scale = _stream(n=8, e=12, f=3, pad_every=0)
    src = jnp.asarray([7, 0, 9, -1, 3, 8, 1, 2, 50, 4, -1, 5], jnp.int32)
    dst = jnp.asarray([0, 9, 1, 2, -1, 3, 8, 4, 5, 50, 6, 7], jnp.int32)
    xla = np.asarray(A.gather_aggregate(
        agg, x, src, dst, 8, scale=scale, backend="xla"))
    pal = np.asarray(A.gather_aggregate(
        agg, x, src, dst, 8, scale=scale, backend="pallas",
        edge_block=4, node_block=4))
    assert np.isfinite(xla).all()
    np.testing.assert_allclose(pal, xla, atol=1e-5)
    # only the fully in-range edges contribute
    keep = (np.asarray(src) >= 0) & (np.asarray(src) < 8) \
        & (np.asarray(dst) >= 0) & (np.asarray(dst) < 8)
    want = np.asarray(A.gather_aggregate(
        agg, x, jnp.asarray(np.where(keep, src, -1)), dst, 8,
        scale=scale, backend="xla"))
    np.testing.assert_allclose(xla, want, atol=1e-5)


def test_gather_aggregate_pallas_var_falls_back_to_materialized():
    """var/std are outside the fused family: the pallas backend routes
    them through the materialized segment kernel with identical numerics."""
    x, src, dst, _ = _stream()
    got = np.asarray(A.gather_aggregate(
        "var", x, src, dst, 37, backend="pallas", edge_block=16,
        node_block=8))
    want = np.asarray(A.gather_aggregate(
        "var", x, src, dst, 37, backend="xla"))
    np.testing.assert_allclose(got, want, atol=1e-5)


# ------------------------------------------- conv-level fused parity ----
@pytest.mark.parametrize("conv,precision", parity.conv_precision_cases())
def test_fused_packed_matches_materialized(conv, precision):
    """The packed cell of the shared parity matrix: apply_packed traced
    under the pallas backend (fused gather for linear convs, segment /
    segment-softmax kernels elsewhere) == the materialized XLA trace
    under one calibrated PrecisionPolicy, for every registered conv and
    every precision its ConvSpec declares, on a batch holding an
    empty-edge graph and all-padding tail edge blocks; fp32 also checks
    the padded per-graph oracle."""
    gs = [P.make_graph(DS, i) for i in range(5)]
    gs.insert(2, _empty_edge_graph())
    parity.check_packed(conv, precision, gs, DS)


@pytest.mark.parametrize("conv", ["gcn", "sage"])
@pytest.mark.parametrize("dataflow", C.DATAFLOWS)
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_dataflow_overrides_preserve_numerics(conv, dataflow, backend):
    """Every (dataflow, backend) combination produces the same model
    outputs: the reordering is exact for linear phi."""
    base = _cfg(conv, "auto")
    params = prm.materialize(G.model_plan(base), jax.random.key(1))
    _, jb = _packed_batch()
    with A.backend_scope("xla"):
        ref = np.asarray(jax.jit(lambda p, b: G.apply_packed(
            p, base, b))(params, jb))
    cfg = dataclasses.replace(base, gnn_dataflow=dataflow)
    with A.backend_scope(backend, 32, 16):
        got = np.asarray(jax.jit(lambda p, b: G.apply_packed(
            p, cfg, b))(params, jb))
    assert float(np.max(np.abs(got - ref))) < 1e-4, (dataflow, backend)


def test_fused_node_task_isolated_nodes():
    """Node-level outputs (not just pooled graph outputs) agree on a
    batch whose zero-edge graph makes whole node rows isolated."""
    cfg = _cfg("gcn", task="node")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(2))
    _, jb = _packed_batch()
    with A.backend_scope("xla"):
        ref = np.asarray(jax.jit(
            lambda p, b: G.apply_packed(p, cfg, b))(params, jb))
    with A.backend_scope("pallas", 32, 16):
        got = np.asarray(jax.jit(
            lambda p, b: G.apply_packed(p, cfg, b))(params, jb))
    assert float(np.max(np.abs(got - ref))) < 1e-4


# --------------------------------------------------- dataflow planner ---
def test_resolve_dataflow_auto_rule():
    """auto == transform_first exactly when out_dim < in_dim (GCN/SAGE);
    GIN/PNA never reorder; explicit overrides win."""
    for conv in C.REORDERABLE_CONVS:
        assert C.resolve_dataflow(
            C.ConvConfig(16, 8, conv=conv)) == "transform_first"
        assert C.resolve_dataflow(
            C.ConvConfig(8, 16, conv=conv)) == "aggregate_first"
        assert C.resolve_dataflow(
            C.ConvConfig(16, 16, conv=conv)) == "aggregate_first"
        assert C.resolve_dataflow(C.ConvConfig(
            16, 8, conv=conv, dataflow="aggregate_first")) \
            == "aggregate_first"
        assert C.resolve_dataflow(C.ConvConfig(
            8, 16, conv=conv, dataflow="transform_first")) \
            == "transform_first"
    for conv in ("gin", "pna"):
        assert C.resolve_dataflow(C.ConvConfig(
            16, 8, conv=conv, dataflow="transform_first")) \
            == "aggregate_first"
    with pytest.raises(ValueError):
        C.resolve_dataflow(C.ConvConfig(8, 8, dataflow="bogus"))


def test_dataflow_cost_model():
    """The closed-form cost prices the edge stream at aggregation width:
    degree scales the gap, the sign follows out_dim - in_dim."""
    c = C.dataflow_cost(64, 16, 2.0)
    assert c["transform_first"] < c["aggregate_first"]
    c = C.dataflow_cost(16, 64, 2.0)
    assert c["aggregate_first"] < c["transform_first"]
    gap4 = C.dataflow_cost(64, 16, 4.0)
    gap2 = C.dataflow_cost(64, 16, 2.0)
    assert (gap4["aggregate_first"] - gap4["transform_first"]) \
        > (gap2["aggregate_first"] - gap2["transform_first"])


def test_dataflow_in_dse_and_perf_features():
    """The dataflow axis is sampled, reaches the model config, and is
    featurized; old databases without the key still featurize with the
    auto default."""
    from repro.core import dse
    from repro.core import perf_model as PM
    rng = np.random.default_rng(0)
    ds = [dse.sample_design(rng) for _ in range(32)]
    assert all(d["dataflow"] in dse.SPACE["dataflow"] for d in ds)
    assert len({d["dataflow"] for d in ds}) > 1
    d = ds[0]
    assert dse.design_to_config(d).gnn_dataflow == d["dataflow"]
    v = PM.features(d)
    assert len(v) == len(PM.FEATURE_NAMES)
    i_tf = PM.FEATURE_NAMES.index("dataflow_transform_first")
    i_af = PM.FEATURE_NAMES.index("dataflow_aggregate_first")
    assert v[i_tf] == float(d["dataflow"] == "transform_first")
    assert v[i_af] == float(d["dataflow"] == "aggregate_first")
    # pre-dataflow database record: defaults preserved
    legacy = dict(d)
    legacy.pop("dataflow")
    w = PM.features(legacy)
    assert len(w) == len(PM.FEATURE_NAMES)
    assert w[i_tf] == 0.0 and w[i_af] == 0.0
    # the resolved width prices the reordering
    i_width = PM.FEATURE_NAMES.index("agg_width_last")
    wide = dict(d, conv="gcn", dataflow="auto", gnn_layers=2,
                gnn_hidden_dim=256, gnn_out_dim=64)
    narrow = dict(wide, dataflow="aggregate_first")
    assert PM.features(wide)[i_width] == 64.0
    assert PM.features(narrow)[i_width] == 256.0


def test_gcn_scales_precomputed_and_consistent():
    """graph_inputs/packed_inputs carry the hoisted GCN norm scales, and
    gcn_apply produces identical outputs whether or not they are present
    (direct callers without the precompute still work)."""
    gs, jb = _packed_batch()
    g, x, _, _ = G.packed_inputs(jb)
    assert "gcn_edge_scale" in g and "gcn_self_scale" in g
    valid = np.asarray(g["valid_e"])
    es = np.asarray(g["gcn_edge_scale"])
    assert np.all(es[~valid] == 0.0)
    cfg = C.ConvConfig(in_dim=11, out_dim=8, conv="gcn")
    params = prm.materialize(C.conv_plan(cfg), jax.random.key(3))
    out = np.asarray(C.conv_apply(params, g, x, cfg))
    bare = {k: v for k, v in g.items()
            if k not in ("gcn_edge_scale", "gcn_self_scale")}
    out2 = np.asarray(C.conv_apply(params, bare, x, cfg))
    np.testing.assert_allclose(out, out2, atol=1e-6)


# ------------------------------------------------- serve-path fallback --
def test_drain_gnn_queue_oversize_fallback():
    """Graphs too large for the packed budgets are answered through the
    padded per-graph oracle (not dropped), and stats report the split."""
    from repro.launch.serve import drain_gnn_queue
    cfg = _cfg("gcn")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(4))
    big = P.GraphDataConfig(avg_nodes=40, max_nodes=64, max_edges=64,
                            node_feat_dim=11, edge_feat_dim=4, seed=6)
    queue = [P.make_graph(DS, i) for i in range(6)] \
        + [P.make_graph(big, 0)]
    node_budget, edge_budget = 32, 96     # the big graph cannot fit
    assert not P.graph_fits_budget(queue[-1], node_budget, edge_budget)
    fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))
    fallback = jax.jit(lambda p, el: G.apply(p, cfg, el))
    outs, stats = drain_gnn_queue(fn, params, queue, node_budget,
                                  edge_budget, 8, fallback)
    assert stats["fallback_served"] == 1
    assert stats["dropped"] == 0
    assert stats["served"] == len(queue)
    assert stats["served"] == stats["packed_served"] \
        + stats["fallback_served"]
    # the fallback answer equals the padded oracle run directly
    el = {"node_feat": jnp.asarray(queue[-1].node_feat),
          "edge_index": jnp.asarray(queue[-1].edge_index),
          "edge_feat": jnp.asarray(queue[-1].edge_feat),
          "num_nodes": jnp.int32(queue[-1].num_nodes)}
    want = np.asarray(fallback(params, el))
    np.testing.assert_allclose(np.asarray(outs[-1]), want, atol=1e-6)
    # with a fallback every request's outcome is a served status
    assert [o["status"] for o in stats["outcomes"]] \
        == ["served_packed"] * 6 + ["served_fallback"]
    assert stats["rejected_oversize"] == 0
    # without a fallback_fn the oversize request gets an explicit
    # per-request rejected_oversize outcome (with a reason), not a
    # silent drop; "dropped" stays as the legacy alias
    _, stats2 = drain_gnn_queue(fn, params, queue, node_budget,
                                edge_budget, 8)
    assert stats2["dropped"] == 1 and stats2["fallback_served"] == 0
    assert stats2["rejected_oversize"] == 1
    (rej,) = [o for o in stats2["outcomes"]
              if o["status"] == "rejected_oversize"]
    assert rej["index"] == 6 and "exceed the packed budgets" in rej["reason"]
