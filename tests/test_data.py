"""Data pipeline: determinism (preemption-safe resume) + graph validity."""
import numpy as np

from repro.data import pipeline as P


def test_token_batch_deterministic():
    cfg = P.TokenDataConfig(vocab_size=100, seq_len=16, global_batch=4)
    b1, b2 = P.token_batch(cfg, 7), P.token_batch(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = P.token_batch(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_token_labels_shifted():
    cfg = P.TokenDataConfig(vocab_size=50, seq_len=8, global_batch=2)
    b = P.token_batch(cfg, 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_graphs_valid_and_deterministic():
    cfg = P.GraphDataConfig(num_graphs=10)
    g1, g2 = P.make_graph(cfg, 3), P.make_graph(cfg, 3)
    np.testing.assert_array_equal(g1.edge_index, g2.edge_index)
    assert 0 < g1.num_nodes <= cfg.max_nodes
    e = g1.edge_index[:g1.num_edges]
    assert (e[:, 0] >= 0).all() and (e[:, 0] < g1.num_nodes).all()
    assert (g1.edge_index[g1.num_edges:] == -1).all()
    # undirected pairs present
    pairs = {(int(s), int(d)) for s, d in e}
    assert all((d, s) in pairs for s, d in list(pairs)[:20])


def test_graph_batch_resume_alignment():
    cfg = P.GraphDataConfig(num_graphs=20)
    b1 = P.graph_batch(cfg, step=3, batch_size=4)
    b2 = P.graph_batch(cfg, step=3, batch_size=4)
    np.testing.assert_array_equal(b1["node_feat"], b2["node_feat"])


def test_dataset_stats_helpers():
    cfg = P.GraphDataConfig(num_graphs=30, avg_nodes=18)
    ds = P.graph_dataset(cfg)
    n, e = P.compute_average_nodes_and_edges(ds)
    assert 10 <= n <= 26
    assert P.compute_average_degree(ds) > 1.0
    n2, e2 = P.compute_median_nodes_and_edges(ds)
    assert isinstance(n2, int) and n2 > 0


def test_validate_graph_accepts_wellformed():
    cfg = P.GraphDataConfig(num_graphs=8)
    for i in range(8):
        assert P.validate_graph(P.make_graph(cfg, i)) is None


def test_validate_graph_rejects_malformed():
    """Each malformation names a reason; padding rows (-1 src beyond
    num_edges, zeroed features) are the format's own and stay legal."""
    import dataclasses
    g = P.make_graph(P.GraphDataConfig(), 0)

    def mutated(**kw):
        return dataclasses.replace(g, **kw)

    # negative endpoint inside the active prefix
    ei = np.array(g.edge_index, copy=True)
    ei[0, 0] = -1
    assert "out of range" in P.validate_graph(mutated(edge_index=ei))
    # endpoint >= num_nodes
    ei = np.array(g.edge_index, copy=True)
    ei[1, 1] = g.num_nodes
    assert "out of range" in P.validate_graph(mutated(edge_index=ei))
    # shape mismatches
    assert "2-D" in P.validate_graph(mutated(node_feat=g.node_feat[:, 0]))
    assert "(max_edges, 2)" in P.validate_graph(
        mutated(edge_index=g.edge_index[:, :1]))
    assert "rows" in P.validate_graph(mutated(edge_feat=g.edge_feat[:-1]))
    # counts outside the buffer
    assert "num_nodes" in P.validate_graph(
        mutated(num_nodes=g.node_feat.shape[0] + 1))
    assert "num_edges" in P.validate_graph(mutated(num_edges=-1))
    # non-finite features in the active prefix only
    nf = np.array(g.node_feat, copy=True)
    nf[0, 0] = np.nan
    assert "node features" in P.validate_graph(mutated(node_feat=nf))
    ef = np.array(g.edge_feat, copy=True)
    ef[0, 0] = np.inf
    assert "edge features" in P.validate_graph(mutated(edge_feat=ef))
    # the same poison *outside* the active prefix is padding: legal
    nf2 = np.array(g.node_feat, copy=True)
    nf2[g.num_nodes:, :] = np.nan
    assert P.validate_graph(mutated(node_feat=nf2)) is None
