"""Property tests for the per-destination-segment softmax behind GAT
attention: three-way parity (Pallas kernel == ref.py mirror == XLA
segment path), per-segment normalization, edge-permutation and
logit-translation invariance, degenerate shapes (empty segments,
single-edge segments, all-padding edge blocks, -inf masked logits) and
the +-1e4 numerical-stability pin on both backends.

The properties run as seeded random sweeps (test_segment_kernel.py
style); when the optional ``hypothesis`` package is installed the same
property checkers also run under generated examples — the container
ships without it, so those tests skip silently rather than pip-pulling
a dependency.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import aggregations as A
from repro.kernels.segment_softmax.ops import (
    segment_softmax as pallas_segment_softmax)
from repro.kernels.segment_softmax.ref import segment_softmax_ref

try:                                     # optional property-test engine
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(23)
ATOL = 1e-6


def _weights(logits, seg, n, valid=None, edge_block=32):
    """Three-way parity, then return the kernel's weights."""
    z = jnp.asarray(logits, jnp.float32)
    s = jnp.asarray(seg, jnp.int32)
    v = None if valid is None else jnp.asarray(valid)
    got = np.asarray(pallas_segment_softmax(
        z, s, v, num_segments=n, edge_block=edge_block))
    xla = np.asarray(A.segment_softmax(z, s, n, v, backend="xla"))
    seg_eff = np.asarray(s)
    if valid is not None:
        seg_eff = np.where(np.asarray(valid), seg_eff, -1)
    ref = np.asarray(segment_softmax_ref(z, jnp.asarray(seg_eff), n))
    np.testing.assert_allclose(got, xla, atol=ATOL, rtol=1e-5)
    np.testing.assert_allclose(got, ref, atol=ATOL, rtol=1e-5)
    assert np.isfinite(got).all()
    return got


def _sums(w, seg, n, valid=None):
    ok = (np.asarray(seg) >= 0) & (np.asarray(seg) < n)
    if valid is not None:
        ok &= np.asarray(valid)
    seg_safe = np.where(ok, np.asarray(seg), n)
    return np.bincount(seg_safe, weights=np.where(ok, w, 0.0),
                       minlength=n + 1)[:n], ok


def _check_normalized(logits, seg, n, valid=None, edge_block=32):
    """The core contract: nonempty segments sum to 1, weights on
    padding / overflow / masked edges are exactly zero."""
    w = _weights(logits, seg, n, valid, edge_block)
    sums, ok = _sums(w, seg, n, valid)
    nonempty = np.bincount(np.where(ok, np.asarray(seg), n),
                           minlength=n + 1)[:n] > 0
    np.testing.assert_allclose(sums[nonempty], 1.0, atol=1e-5)
    np.testing.assert_allclose(sums[~nonempty], 0.0, atol=0.0)
    assert np.all(w[~ok] == 0.0)
    return w


# --------------------------------------------------- seeded sweeps ------
@pytest.mark.parametrize("e,n,eb,seed", [
    (200, 40, 64, 0),
    (77, 33, 32, 1),             # ragged: padding in both axes
    (128, 8, 128, 2),            # single edge block
    (96, 96, 16, 3),             # more segments than fit one node block
])
def test_parity_and_normalization(e, n, eb, seed):
    rng = np.random.default_rng(seed)
    z = rng.standard_normal(e).astype(np.float32) * 3.0
    # hostile ids: pad (-1), in-range, overflow bucket (n), beyond (n+1)
    seg = rng.integers(-1, n + 2, e).astype(np.int32)
    valid = rng.random(e) < 0.8
    _check_normalized(z, seg, n, valid, eb)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_edge_permutation_invariance(seed):
    """Permuting the edge stream permutes the weights and nothing else:
    the per-segment distribution is a set property, not an order one."""
    rng = np.random.default_rng(seed)
    e, n = 120, 17
    z = rng.standard_normal(e).astype(np.float32) * 2.0
    seg = rng.integers(-1, n + 1, e).astype(np.int32)
    w = _weights(z, seg, n)
    perm = rng.permutation(e)
    wp = _weights(z[perm], seg[perm], n)
    np.testing.assert_allclose(wp, w[perm], atol=ATOL, rtol=1e-5)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_logit_translation_invariance(seed):
    """Adding any per-segment constant to the logits leaves the weights
    unchanged — the online max subtraction cancels it exactly in real
    arithmetic and to tolerance in float."""
    rng = np.random.default_rng(seed)
    e, n = 90, 11
    z = rng.standard_normal(e).astype(np.float32)
    seg = rng.integers(0, n, e).astype(np.int32)
    shift = rng.uniform(-50.0, 50.0, n).astype(np.float32)
    w = _weights(z, seg, n)
    ws = _weights(z + shift[seg], seg, n)
    np.testing.assert_allclose(ws, w, atol=1e-5, rtol=1e-4)


# ------------------------------------------------- degenerate shapes ----
def test_single_edge_segments_weight_one():
    n = 12
    z = RNG.standard_normal(n).astype(np.float32) * 100.0
    seg = np.arange(n, dtype=np.int32)
    w = _check_normalized(z, seg, n, edge_block=8)
    np.testing.assert_allclose(w, 1.0, atol=1e-6)


def test_empty_stream_and_empty_segments():
    w = np.asarray(pallas_segment_softmax(
        jnp.zeros((0,), jnp.float32), jnp.zeros((0,), jnp.int32),
        num_segments=9))
    assert w.shape == (0,)
    # every edge lands on segment 3: the other 8 segments are empty
    z = RNG.standard_normal(24).astype(np.float32)
    seg = np.full((24,), 3, np.int32)
    _check_normalized(z, seg, 9, edge_block=8)


def test_all_padding_edge_block():
    """A whole edge block of padding must not perturb the running
    max/sum of neighbouring blocks."""
    eb = 16
    z = RNG.standard_normal(3 * eb).astype(np.float32) * 5.0
    seg = RNG.integers(0, 6, 3 * eb).astype(np.int32)
    seg[eb:2 * eb] = -1
    _check_normalized(z, seg, 6, edge_block=eb)


def test_neg_inf_masked_logits():
    """-inf logits are hard masks: zero weight, the rest of the segment
    renormalizes; a segment that is *all* -inf yields zero weights (not
    NaN — the finite NEG_INF clamp keeps exp(-inf - m) defined)."""
    n = 4
    z = np.array([0.0, 1.0, -np.inf, 0.5,
                  -np.inf, -np.inf,
                  2.0], np.float32)
    seg = np.array([0, 0, 0, 1, 2, 2, 3], np.int32)
    w = _weights(z, seg, n, edge_block=4)
    assert w[2] == 0.0
    np.testing.assert_allclose(w[:2].sum(), 1.0, atol=1e-6)
    np.testing.assert_allclose(w[4:6], 0.0, atol=0.0)   # all-masked seg
    np.testing.assert_allclose(w[[3, 6]], 1.0, atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_extreme_logits_stable(backend):
    """The stability regression pin: +-1e4 logits (far past exp's fp32
    range) produce finite, normalized weights on both backends — the
    online max subtraction means exp never sees a positive argument."""
    rng = np.random.default_rng(7)
    e, n = 160, 13
    z = rng.choice([-1e4, -5e3, 0.0, 5e3, 1e4], e).astype(np.float32)
    seg = rng.integers(-1, n + 1, e).astype(np.int32)
    w = np.asarray(A.segment_softmax(
        jnp.asarray(z), jnp.asarray(seg), n, backend=backend,
        edge_block=32))
    assert np.isfinite(w).all()
    sums, ok = _sums(w, seg, n)
    nonempty = np.bincount(np.where(ok, seg, n), minlength=n + 1)[:n] > 0
    np.testing.assert_allclose(sums[nonempty], 1.0, atol=1e-5)
    # the max logit in every nonempty segment dominates or ties: its
    # weight is the largest of the segment
    for s in np.flatnonzero(nonempty):
        m = seg[ok] == s
        assert w[ok][m].max() == w[ok][m][z[ok][m].argmax()]


# ------------------------------------------- hypothesis (if installed) --
if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_hypothesis_normalization_and_invariances(data):
        n = data.draw(st.integers(1, 24), label="num_segments")
        e = data.draw(st.integers(0, 96), label="num_edges")
        seg = np.asarray(data.draw(
            st.lists(st.integers(-1, n + 1), min_size=e, max_size=e),
            label="seg_ids"), np.int32).reshape(e)
        z = np.asarray(data.draw(
            st.lists(st.floats(-1e4, 1e4, width=32),
                     min_size=e, max_size=e),
            label="logits"), np.float32).reshape(e)
        w = _check_normalized(z, seg, n, edge_block=16)
        if e:
            perm = np.asarray(data.draw(st.permutations(range(e)),
                                        label="perm"), np.int64)
            wp = _weights(z[perm], seg[perm], n, edge_block=16)
            np.testing.assert_allclose(wp, w[perm], atol=1e-5, rtol=1e-4)
