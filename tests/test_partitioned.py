"""Giant-graph partitioned inference: partition_graph coverage and
budget invariants, degenerate shapes (single part, no edges, more parts
than nodes, disconnected components, everything cut), partitioned-vs-
padded-oracle parity over the conv x precision x backend grid on
simulated host devices, oversize routing (every oversize request
resolves to exactly one of partitioned / fallback / rejected), and the
DSE ``partition`` axis plumbing."""
import dataclasses

import numpy as np
import pytest

import parity
from repro.core import convs as Cv
from repro.core import dse
from repro.core import perf_model as PM
from repro.core.quantization import BYTE_WIDTHS
from repro.data import pipeline as P
from repro.runtime import scheduler as S

DS = P.GraphDataConfig(avg_nodes=24, avg_degree=2, node_feat_dim=7,
                       edge_feat_dim=3, max_nodes=64, max_edges=96,
                       seed=11)
GID_SENTINEL = 2 ** 30


def _graph(n, edges, max_nodes=64, max_edges=96, f=7, fe=3):
    """Padded Graph with an explicit edge list (structure-exact tests)."""
    rng = np.random.default_rng(3)
    nf = np.zeros((max_nodes, f), np.float32)
    nf[:n] = rng.normal(size=(n, f)).astype(np.float32)
    ei = np.full((max_edges, 2), -1, np.int32)
    ef = np.zeros((max_edges, fe), np.float32)
    for i, (s, d) in enumerate(edges):
        ei[i] = (s, d)
        ef[i] = rng.normal(size=(fe,)).astype(np.float32)
    return P.Graph(node_feat=nf, edge_index=ei, edge_feat=ef,
                   num_nodes=n, num_edges=len(edges),
                   y=np.zeros((1,), np.float32))


def _owned_gids(batch):
    gid = np.asarray(batch["node_global_id"])
    return gid[gid < GID_SENTINEL]


# ------------------------------------------------ partition invariants --
def test_partition_covers_every_node_and_edge_exactly_once():
    g = P.make_graph(DS, 0)
    part = P.partition_graph(g, 3, 48, 96)
    assert part.total_nodes == g.num_nodes
    assert part.total_edges == g.num_edges
    assert part.padded_nodes == g.node_feat.shape[0]
    # node ownership is a partition: every global id exactly once
    owned = np.concatenate([_owned_gids(b) for b in part.parts])
    assert sorted(owned.tolist()) == list(range(g.num_nodes))
    # edge ownership is a partition: per-part valid edges sum to e
    per_part_e = [int((np.asarray(b["edge_index"])[:, 0] >= 0).sum())
                  for b in part.parts]
    assert sum(per_part_e) == g.num_edges
    src = g.edge_index[:g.num_edges, 0]
    dst = g.edge_index[:g.num_edges, 1]
    owner = np.empty((g.num_nodes,), np.int64)
    for p, b in enumerate(part.parts):
        owner[_owned_gids(b)] = p
    assert part.cut_edges == int((owner[src] != owner[dst]).sum())
    indeg = np.bincount(dst, minlength=g.num_nodes)
    for p, b in enumerate(part.parts):
        own = _owned_gids(b)
        n_own = len(own)
        active = int(b["graph_num_nodes"][0])
        # packed layout: owned rows first, features copied verbatim
        np.testing.assert_array_equal(b["node_feat"][:n_own],
                                      g.node_feat[own])
        # owned rows carry true *global* in-degrees (exact GCN norm)
        np.testing.assert_array_equal(b["node_in_deg"][:n_own],
                                      indeg[own].astype(np.float32))
        # every owned edge's dst is an owned local row; halo rows only
        # ever appear as sources
        ei = np.asarray(b["edge_index"])
        valid = ei[:, 0] >= 0
        assert ei[valid, 1].max(initial=-1) < n_own
        assert ei[valid, 0].max(initial=-1) < active
        # halo exchange indices: sends publish owned rows, receives
        # overwrite halo rows (never owned ones)
        hs = np.asarray(b["halo_send"])
        assert np.all(hs[hs >= 0] < n_own)
        hd = np.asarray(b["halo_recv_dst"])
        live = hd < part.node_budget
        assert np.all(hd[live] >= n_own) and np.all(hd[live] < active)
        assert int(b["total_nodes"]) == g.num_nodes


def test_partition_budget_violations_raise():
    chain = _graph(8, [(i, i + 1) for i in range(7)])
    with pytest.raises(ValueError, match="node_budget"):
        P.partition_graph(chain, 2, 4, 96)       # 4 owned + halo > 4
    with pytest.raises(ValueError, match="edge_budget"):
        P.partition_graph(chain, 2, 64, 1)
    with pytest.raises(ValueError, match="halo_budget"):
        P.partition_graph(chain, 2, 64, 96, halo_budget=0)
    with pytest.raises(ValueError, match="num_parts"):
        P.partition_graph(chain, 0, 64, 96)


def test_partition_single_part_is_halo_free():
    g = P.make_graph(DS, 1)
    part = P.partition_graph(g, 1, 64, 96)
    assert part.num_parts == 1 and len(part.parts) == 1
    assert part.cut_edges == 0 and part.halo_nodes == 0
    assert len(_owned_gids(part.parts[0])) == g.num_nodes
    assert np.all(np.asarray(part.parts[0]["halo_send"]) == -1)


def test_partition_edgeless_graph():
    g = _graph(6, [])
    part = P.partition_graph(g, 2, 8, 8)
    assert part.cut_edges == 0 and part.halo_nodes == 0
    assert sorted(len(_owned_gids(b)) for b in part.parts) == [3, 3]


def test_partition_more_parts_than_nodes_keeps_shapes():
    g = _graph(2, [(0, 1)])
    part = P.partition_graph(g, 4, 8, 8)
    counts = sorted(len(_owned_gids(b)) for b in part.parts)
    assert counts == [0, 0, 1, 1]
    for b in part.parts:
        assert b["node_feat"].shape == part.parts[0]["node_feat"].shape
        assert int(b["num_graphs"]) == 1


def test_partition_disconnected_components_cut_free():
    """BFS-ordered greedy keeps whole components together: two equal
    chains over two parts cut zero edges and exchange nothing."""
    edges = [(i, i + 1) for i in range(3)] + [(1, 0)] \
        + [(4 + i, 5 + i) for i in range(3)] + [(5, 4)]
    g = _graph(8, edges)
    part = P.partition_graph(g, 2, 8, 8)
    assert part.cut_edges == 0 and part.halo_nodes == 0
    comps = [sorted(_owned_gids(b).tolist()) for b in part.parts]
    assert sorted(comps) == [[0, 1, 2, 3], [4, 5, 6, 7]]


def test_partition_every_edge_cut():
    """One node per part: both directions of the only pair cross the
    cut and each part replicates the other's node as halo."""
    g = _graph(2, [(0, 1), (1, 0)])
    part = P.partition_graph(g, 2, 8, 8)
    assert part.cut_edges == 2 == g.num_edges
    assert part.halo_nodes == 2
    for b in part.parts:
        assert int(b["graph_num_nodes"][0]) == 2   # 1 owned + 1 halo


def test_comm_bytes_matches_dse_model():
    g = P.make_graph(DS, 2)
    part = P.partition_graph(g, 2, 48, 96)
    assert part.comm_bytes(16, 4.0, 3) == Cv.halo_comm_bytes(
        part.cut_edges, 16, 4.0, 3)
    assert part.comm_bytes(16, 4.0, 3) \
        == part.cut_edges * 16 * 4.0 * 2
    # a single conv layer has no layer boundary: nothing to exchange
    assert part.comm_bytes(16, 4.0, 1) == 0.0
    assert Cv.halo_comm_bytes(100, 16, 4.0, 0) == 0.0


# ------------------------------------------------ oversize routing ------
def _sized(idx, n_nodes, n_edges=4):
    g = P.make_graph(DS, idx)
    return dataclasses.replace(g, num_nodes=n_nodes, num_edges=n_edges)


def _sched(lane, node_budget=20):
    cfg = S.SchedulerConfig(node_budget, 10_000, 4,
                            default_tier=S.SLOTier("standard", 0.25, 1))
    return S.ContinuousScheduler(cfg, [lane])


def test_oversize_served_partitioned_on_mesh_capable_lane():
    lane = S.SimExecutor(S.constant_service(1.0), allow_partition=True,
                         num_partitions=2)
    sched = _sched(lane)
    sched.submit(_sized(0, 40))
    sched.drain()
    assert sched.responses[0].status == S.SERVED_PARTITIONED
    s = sched.summary()
    assert s["partitioned_served"] == 1
    assert s["fallback_served"] == 0 and s["rejected_oversize"] == 0


def test_partition_infeasible_reroutes_to_fallback_same_launch():
    def infeasible(_g):
        raise S.PartitionInfeasible("does not fit per-device budgets")
    lane = S.SimExecutor(S.constant_service(1.0), partition_fn=infeasible)
    sched = _sched(lane)
    sched.submit(_sized(0, 40))
    sched.drain()
    assert sched.responses[0].status == S.SERVED_FALLBACK
    assert len(sched.launches) == 1          # reroute, not a second launch
    s = sched.summary()
    assert s["partitioned_served"] == 0 and s["fallback_served"] == 1


def test_oversize_exactly_one_terminal_status():
    """Mixed feasible/infeasible oversize traffic: every request lands
    in exactly one of partitioned_served / fallback_served /
    rejected_oversize — the double-count bug this PR's admission/launch
    agreement fix closes."""
    def part_fn(g):
        if g.num_nodes % 2:
            raise S.PartitionInfeasible("odd-size graphs refuse to split")
        return None
    lane = S.SimExecutor(S.constant_service(1.0), partition_fn=part_fn,
                         num_partitions=2)
    sched = _sched(lane)
    for i, nn in enumerate([40, 41, 44, 45, 8]):
        sched.submit(_sized(i, nn))
    sched.drain()
    assert sorted(r.req_id for r in sched.responses) == list(range(5))
    s = sched.summary()
    assert s["partitioned_served"] == 2
    assert s["fallback_served"] == 2
    assert s["rejected_oversize"] == 0
    assert s["served"] == 5


def test_wave_drain_matches_continuous_oversize_accounting():
    """simulate_wave_drain (the serve.py wave oracle) classifies
    oversize through the same can_partition predicate."""
    def part_fn(g):
        if g.num_nodes > 50:
            raise S.PartitionInfeasible("beyond the partitioned lane")
        return None
    cfg = S.SchedulerConfig(20, 10_000, 2,
                            default_tier=S.SLOTier("standard", 0.25, 1))
    lane = S.SimExecutor(S.constant_service(1.0), partition_fn=part_fn,
                         allow_fallback=False, num_partitions=2)
    trace = [(0.1 * i, _sized(i, nn), "default")
             for i, nn in enumerate([8, 40, 60, 8, 44])]
    _, summary = S.simulate_wave_drain(trace, cfg, lane)
    assert summary["partitioned_served"] == 2
    assert summary["fallback_served"] == 0
    assert summary["rejected_oversize"] == 1     # 60 nodes, no fallback
    assert summary["served"] == 4


# ------------------------------------------------ DSE / feature axis ----
def test_space_has_partition_and_features_roundtrip():
    rng = np.random.default_rng(0)
    assert 1 in dse.SPACE["partition"]
    d = dse.sample_design(rng)
    assert d["partition"] in dse.SPACE["partition"]
    v = PM.features(d)
    assert len(v) == len(PM.FEATURE_NAMES)
    assert v[PM.FEATURE_NAMES.index("partition")] == float(d["partition"])
    halo = v[PM.FEATURE_NAMES.index("halo_comm_bytes")]
    if d["partition"] == 1:
        assert halo == 0.0
    else:
        p = d["partition"]
        cut = (p - 1) / p * d.get("edge_budget", d["avg_edges"])
        assert halo == pytest.approx(Cv.halo_comm_bytes(
            cut, d["gnn_hidden_dim"],
            BYTE_WIDTHS[d.get("precision", "fp32")],
            d["gnn_layers"]))


def test_legacy_design_featurizes_as_unpartitioned():
    """Databases recorded before the partition axis still featurize:
    partition defaults to 1 with zero modeled exchange volume."""
    rng = np.random.default_rng(1)
    d = dse.sample_design(rng)
    d.pop("partition", None)
    v = PM.features(d)
    assert len(v) == len(PM.FEATURE_NAMES)
    assert v[PM.FEATURE_NAMES.index("partition")] == 1.0
    assert v[PM.FEATURE_NAMES.index("halo_comm_bytes")] == 0.0


# --------------------------------- parity (simulated host devices) ------
# The device count must be pinned before jax initializes, so the grid
# runs in one subprocess over 4 simulated host devices: every
# registered conv, every precision its ConvSpec declares, both
# aggregation backends, partitioned-vs-padded-oracle. Convs whose
# ConvSpec sets partition_bitwise (gcn, and gat — per-destination edge
# order survives the edge-cut, so the segment softmax and sum
# accumulate in the padded program's order) are asserted *bitwise* at
# fp32 (the serve-path acceptance contract); everything else to a
# tight tolerance — pna fp32 reduces its degree statistics in a
# different association order across devices (~2e-6 at these widths),
# which bitwise would spuriously fail. The grid body lives in
# tests/parity.py next to the packed and sharded cells of the matrix.
@pytest.mark.budget(840)
def test_partitioned_parity_grid_subprocess():
    parity.run_parity_subprocess(parity.partitioned_parity_script(),
                                 "PARTITIONED_PARITY_OK")
