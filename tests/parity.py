"""Shared cross-backend parity harness: one conv x precision x backend
matrix, driven by the conv registry, reused by the packed
(test_fused_gather), sharded (test_sharded) and partitioned
(test_partitioned) parity suites.

Before this module the three suites each hardcoded their own
``("gcn", "sage", "gin", "pna")`` x ``("fp32", "bf16", "int8")`` grid —
adding a conv meant editing every test file and hoping none was missed.
Now the axes come from ``repro.core.convs.CONV_REGISTRY``:

* ``conv_axis()`` — every registered conv, in registration order;
* ``precision_axis(conv)`` — the precisions its ConvSpec declares
  (attention convs still list int8: only the projection and the
  aggregation stream quantize, the attention math itself is pinned to
  fp32 — see core/aggregations.segment_softmax);
* ``bitwise_convs()`` — convs whose ConvSpec promises *bitwise*
  fp32 partitioned parity against the padded oracle (the serve-path
  acceptance contract); the partitioned grid asserts array_equal for
  exactly this set and a 1e-4 tolerance for the rest (pna reduces its
  degree statistics in a different association order across devices).

``register_conv`` fires registry listeners, so a conv registered in a
test process appears in these axes — and therefore in the grid
parametrization — without touching any test file
(test_conv_registry.py pins that property).

The sharded/partitioned grids need the simulated device count pinned
before jax initializes, so they run as subprocess scripts; the scripts
import the registry in the child and derive the same axes there.
"""
import os
import subprocess
import sys
import textwrap

BACKENDS = ("xla", "pallas")

# packed-grid tolerances: xla-vs-pallas under one PrecisionPolicy — the
# backends share the quantization, so only aggregation order differs
PACKED_ATOL = {"fp32": 1e-4, "bf16": 1e-4, "int8": 1e-4}
ORACLE_ATOL = 1e-4          # fp32 packed vs the padded per-graph oracle


def conv_axis():
    """Every registered conv — the rows of the parity matrix."""
    from repro.core import convs as Cv
    return tuple(Cv.CONV_TYPES)


def precision_axis(conv):
    """The precisions this conv's ConvSpec declares."""
    from repro.core import convs as Cv
    return tuple(Cv.conv_spec(conv).precisions)


def conv_precision_cases():
    """(conv, precision) pairs for pytest.mark.parametrize."""
    return [(c, p) for c in conv_axis() for p in precision_axis(c)]


def bitwise_convs():
    """Convs promising bitwise fp32 partitioned parity."""
    from repro.core import convs as Cv
    return tuple(n for n in Cv.CONV_TYPES
                 if Cv.conv_spec(n).partition_bitwise)


def model_cfg(conv, node_feat_dim=7, edge_feat_dim=3, hidden=8, out=8):
    """The small 2-layer model every parity grid runs."""
    from repro.core import gnn_model as G
    return G.GNNModelConfig(
        graph_input_feature_dim=node_feat_dim,
        graph_input_edge_dim=edge_feat_dim,
        gnn_hidden_dim=hidden, gnn_num_layers=2, gnn_output_dim=out,
        gnn_conv=conv,
        mlp_head=G.MLPConfig(in_dim=out * 3, out_dim=1, hidden_dim=8,
                             hidden_layers=1))


def check_packed(conv, precision, graphs, ds, atol=None):
    """The packed cell of the matrix: apply_packed traced under the
    pallas backend == the materialized XLA trace under one calibrated
    PrecisionPolicy; at fp32 also == the padded per-graph oracle."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import aggregations as A
    from repro.core import gnn_model as G
    from repro.data import pipeline as P
    from repro.nn import param as prm

    cfg = model_cfg(conv, ds.node_feat_dim, ds.edge_feat_dim)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    batch, k = P.pack_graphs(graphs, 128, 256, 8)
    assert k == len(graphs)
    jb = {kk: jnp.asarray(v) for kk, v in batch.items() if kk != "y"}
    policy = None
    if precision != "fp32":
        policy = G.calibrated_policy(params, cfg, jb, precision)
    outs = {}
    for backend in BACKENDS:
        with A.backend_scope(backend, 32, 16):
            outs[backend] = np.asarray(jax.jit(
                lambda p, b: G.apply_packed(p, cfg, b, None, policy))(
                    params, jb))
    err = float(np.max(np.abs(outs["pallas"] - outs["xla"])))
    assert err < (atol or PACKED_ATOL[precision]), (conv, precision, err)
    if precision == "fp32":
        oracle = jax.jit(lambda p, e: G.apply(p, cfg, e))
        for i, g in enumerate(graphs):
            el = {"node_feat": jnp.asarray(g.node_feat),
                  "edge_index": jnp.asarray(g.edge_index),
                  "edge_feat": jnp.asarray(g.edge_feat),
                  "num_nodes": jnp.int32(g.num_nodes)}
            ref = np.asarray(oracle(params, el))
            got = outs["xla"][i]
            assert float(np.max(np.abs(got - ref))) < ORACLE_ATOL, \
                (conv, i)
    return outs


def run_parity_subprocess(script, token, timeout=900):
    """Run a parity grid in a fresh interpreter (the scripts pin
    XLA_FLAGS before jax imports) and assert its success token."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert token in out.stdout, (out.stdout[-2000:], out.stderr[-3000:])


# The shared subprocess header: device pinning, imports, and the
# registry-derived axes (the child re-derives them — same source of
# truth as conv_axis()/precision_axis()/bitwise_convs() above).
SCRIPT_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import convs as Cv
    from repro.core import gnn_model as G
    from repro.data import pipeline as P
    from repro.launch.mesh import make_data_mesh
    from repro.nn import param as prm
    from repro.core import aggregations as agg_mod

    CONVS = tuple(Cv.CONV_TYPES)
    BITWISE = tuple(n for n in CONVS
                    if Cv.conv_spec(n).partition_bitwise)

    def precisions(conv):
        return tuple(Cv.conv_spec(conv).precisions)

    def model_cfg(conv, node_feat_dim=7, edge_feat_dim=3):
        return G.GNNModelConfig(
            graph_input_feature_dim=node_feat_dim,
            graph_input_edge_dim=edge_feat_dim,
            gnn_hidden_dim=8, gnn_num_layers=2, gnn_output_dim=8,
            gnn_conv=conv,
            mlp_head=G.MLPConfig(in_dim=24, out_dim=1, hidden_dim=8,
                                 hidden_layers=1))

    def el(g):
        return {"node_feat": jnp.asarray(g.node_feat),
                "edge_index": jnp.asarray(g.edge_index),
                "edge_feat": jnp.asarray(g.edge_feat),
                "num_nodes": jnp.int32(g.num_nodes)}
""")


def sharded_parity_script():
    """Sharded-vs-single-device over the registry grid on 2 simulated
    host devices, plus host-order gather vs the padded oracle and a
    4-shard wave with idle shards (see test_sharded.py)."""
    return SCRIPT_PRELUDE + textwrap.dedent("""
    DS = P.GraphDataConfig(avg_nodes=10, max_nodes=64, max_edges=64,
                           node_feat_dim=7, edge_feat_dim=3, seed=5)
    graphs = [P.make_graph(DS, i) for i in range(9)]   # uneven over 2

    mesh2 = make_data_mesh(2)
    for conv in CONVS:
        cfg = model_cfg(conv)
        params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
        wave, k = P.shard_pack(graphs, 96, 192, 8, num_shards=2)
        assert k == len(graphs)
        stacked = G.stack_shards(wave)
        cal_batch, _ = P.pack_graphs(graphs, 192, 384, 16)
        for precision in precisions(conv):
            policy = G.calibrated_policy(
                params, cfg, G.packed_to_device(cal_batch), precision)
            for backend in ("xla", "pallas"):
                with agg_mod.backend_scope(backend, 32, 32):
                    fn = G.make_sharded_apply(cfg, mesh2, None, policy)
                    out = np.asarray(fn(params, stacked))
                    single = jax.jit(lambda p, b: G.apply_packed(
                        p, cfg, b, None, policy))
                    for s, shard in enumerate(wave.shards):
                        ref = np.asarray(single(
                            params, G.packed_to_device(shard)))
                        err = np.abs(out[s] - ref).max()
                        assert err < 1e-5, (conv, precision, backend, err)
        # host-order gather vs the padded per-graph oracle (fp32)
        fn = G.make_sharded_apply(cfg, mesh2)
        host = P.gather_shard_outputs(np.asarray(fn(params, stacked)),
                                      wave.index)
        oracle = jax.jit(lambda p, e, c=cfg: G.apply(p, c, e))
        for i, g in enumerate(graphs):
            ref = np.asarray(oracle(params, el(g)))
            assert np.abs(host[i] - ref).max() < 1e-4, (conv, i)
        # 4-shard wave with idle shards: one graph, three empty blocks
        wave4, k4 = P.shard_pack(graphs[:1], 96, 192, 8, num_shards=4)
        assert k4 == 1
        out4 = np.asarray(G.apply_packed_sharded(
            params, cfg, wave4, mesh=make_data_mesh(4)))
        host4 = P.gather_shard_outputs(out4, wave4.index)
        ref = np.asarray(oracle(params, el(graphs[0])))
        assert np.abs(host4[0] - ref).max() < 1e-4, conv
    print("SHARDED_PARITY_OK")
""")


def partitioned_parity_script():
    """Partitioned-vs-padded-oracle over the registry grid on 4
    simulated host devices; BITWISE convs assert array_equal at fp32
    (see test_partitioned.py)."""
    return SCRIPT_PRELUDE + textwrap.dedent("""
    DS = P.GraphDataConfig(avg_nodes=40, avg_degree=2, node_feat_dim=7,
                           edge_feat_dim=3, max_nodes=128, max_edges=192,
                           seed=11)
    g = P.make_graph(DS, 0)
    part4 = P.partition_graph(g, 4, 64, 128)
    stacked4 = G.stack_shards(part4.parts)
    mesh4 = make_data_mesh(4)
    eg = el(g)

    for conv in CONVS:
        cfg = model_cfg(conv)
        params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
        oracle = jax.jit(lambda p, e, c=cfg: G.apply(p, c, e))
        ref32 = np.asarray(oracle(params, eg))
        cal_batch, _ = P.pack_graphs([g], 192, 384, 4)
        for precision in precisions(conv):
            policy = G.calibrated_policy(
                params, cfg, G.packed_to_device(cal_batch), precision)
            for backend in ("xla", "pallas"):
                with agg_mod.backend_scope(backend, 32, 32):
                    fn = G.make_partitioned_apply(
                        cfg, mesh4, None, policy,
                        out_rows=part4.padded_nodes)
                    out = np.asarray(fn(params, stacked4))
                    single = jax.jit(lambda p, b, c=cfg, po=policy:
                                     G.apply_packed(p, c, b, None, po))
                    ref = np.asarray(single(
                        params, G.packed_to_device(cal_batch)))[0]
                    err = np.abs(out - ref).max()
                    assert err < 1e-4, (conv, precision, backend, err)
                    if precision == "fp32" and conv in BITWISE:
                        # bitwise vs the padded oracle built under the
                        # SAME backend (the serve-path contract)
                        refb = np.asarray(jax.jit(
                            lambda p, e: G.apply(p, cfg, e))(params, eg))
                        assert np.array_equal(out, refb), \\
                            (conv, backend, np.abs(out - refb).max())
        # degenerate: 1-part partition over a 1-device mesh is the
        # padded program with an inert exchange — bitwise at fp32
        part1 = P.partition_graph(g, 1, 128, 192)
        out1 = np.asarray(G.apply_packed_partitioned(
            params, cfg, part1, mesh=make_data_mesh(1)))
        assert np.array_equal(out1, ref32), conv

    # degenerate: disconnected components split cut-free -> the SPMD
    # exchange runs with an all-padding halo and must be inert (gcn fp32)
    nf = np.zeros((128, 7), np.float32)
    nf[:8] = np.random.default_rng(1).normal(size=(8, 7)).astype(
        np.float32)
    ei = np.full((192, 2), -1, np.int32)
    edges = [(i, i + 1) for i in range(3)] \\
        + [(4 + i, 5 + i) for i in range(3)]
    for i, (s, d) in enumerate(edges):
        ei[i] = (s, d)
    gd = P.Graph(node_feat=nf, edge_index=ei,
                 edge_feat=np.zeros((192, 3), np.float32),
                 num_nodes=8, num_edges=len(edges),
                 y=np.zeros((1,), np.float32))
    cfg = model_cfg("gcn")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    pd = P.partition_graph(gd, 2, 16, 16)
    assert pd.cut_edges == 0 and pd.halo_nodes == 0
    out = np.asarray(G.apply_packed_partitioned(
        params, cfg, pd, mesh=make_data_mesh(2)))
    ref = np.asarray(jax.jit(lambda p, e: G.apply(p, cfg, e))(
        params, el(gd)))
    assert np.array_equal(out, ref)
    print("PARTITIONED_PARITY_OK")
""")
