"""Direct-fit performance model (numpy random forest) tests."""
import numpy as np

from repro.core import perf_model as PM
from repro.core import dse


def _toy_data(n=200, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, (n, 5))
    y = 3 * x[:, 0] + np.sin(4 * x[:, 1]) + (x[:, 2] > 0.5) * 2 \
        + rng.normal(0, 0.05, n)
    return x, y


def test_tree_beats_mean_predictor():
    x, y = _toy_data()
    tree = PM.DecisionTreeRegressor(max_depth=8).fit(x[:150], y[:150])
    pred = tree.predict(x[150:])
    sse_tree = np.mean((pred - y[150:]) ** 2)
    sse_mean = np.mean((y[150:].mean() - y[150:]) ** 2)
    assert sse_tree < 0.3 * sse_mean


def test_forest_beats_single_tree_generalization():
    x, y = _toy_data(300)
    tree = PM.DecisionTreeRegressor(max_depth=14, min_samples_leaf=1)
    forest = PM.RandomForestRegressor(n_estimators=10, max_depth=14,
                                      min_samples_leaf=1)
    tree.fit(x[:200], y[:200])
    forest.fit(x[:200], y[:200])
    e_tree = np.mean((tree.predict(x[200:]) - y[200:]) ** 2)
    e_forest = np.mean((forest.predict(x[200:]) - y[200:]) ** 2)
    assert e_forest <= e_tree * 1.2


def test_mape():
    assert PM.mape([100, 200], [110, 180]) == 10.0
    assert PM.mape([50], [50]) == 0.0


def test_kfold_cv_runs():
    x, y = _toy_data(120)
    score = PM.kfold_cv_mape(x, np.abs(y) + 1.0, k=5)
    assert 0 < score < 100


def _best_split_reference(self, x, y):
    """The scalar-loop split search the vectorized version replaced;
    pinned here so refactors cannot silently change the fitted trees."""
    n, d = x.shape
    feats = np.arange(d)
    if self.max_features:
        k = max(1, int(d * self.max_features))
        feats = self.rng.choice(d, size=k, replace=False)
    best = (None, None, np.inf)
    for f in feats:
        order = np.argsort(x[:, f], kind="stable")
        xs, ys = x[order, f], y[order]
        csum = np.cumsum(ys)
        csq = np.cumsum(ys * ys)
        total, total_sq = csum[-1], csq[-1]
        ml = self.min_samples_leaf
        for i in range(ml, n - ml + 1):
            if xs[i - 1] == xs[min(i, n - 1)]:
                continue
            sl, sl2 = csum[i - 1], csq[i - 1]
            nl, nr = i, n - i
            sse = (sl2 - sl * sl / nl) \
                + ((total_sq - sl2) - (total - sl) ** 2 / nr)
            if sse < best[2]:
                best = (f, (xs[i - 1] + xs[min(i, n - 1)]) / 2, sse)
    return best


class _ReferenceTree(PM.DecisionTreeRegressor):
    _best_split = _best_split_reference


def test_vectorized_split_matches_scalar_reference():
    """Same splits, same trees: the vectorized prefix-sum SSE search must
    reproduce the original scalar loop's predictions exactly."""
    for seed in range(4):
        x, y = _toy_data(n=150, seed=seed)
        kw = dict(max_depth=10, min_samples_leaf=2)
        fast = PM.DecisionTreeRegressor(
            rng=np.random.default_rng(seed), max_features=0.8, **kw)
        ref = _ReferenceTree(
            rng=np.random.default_rng(seed), max_features=0.8, **kw)
        fast.fit(x[:100], y[:100])
        ref.fit(x[:100], y[:100])
        np.testing.assert_array_equal(fast.predict(x[100:]),
                                      ref.predict(x[100:]))


def test_vectorized_split_faster_smoke():
    """The split search handles a wide, deep fit without pathology."""
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 1, (400, 21))
    y = x @ rng.uniform(-1, 1, 21) + rng.normal(0, 0.1, 400)
    tree = PM.DecisionTreeRegressor(max_depth=12).fit(x, y)
    assert np.mean((tree.predict(x) - y) ** 2) < np.var(y)


def test_feature_vector_shape():
    rng = np.random.default_rng(0)
    d = dse.sample_design(rng)
    f = PM.features(d)
    assert f.shape == (len(PM.FEATURE_NAMES),)
    # the conv one-hot block leads FEATURE_NAMES and is registry-sized
    n_conv = sum(1 for n in PM.FEATURE_NAMES if n.startswith("conv_"))
    assert all(n.startswith("conv_") for n in PM.FEATURE_NAMES[:n_conv])
    assert f[:n_conv].sum() == 1.0    # one-hot conv type


def test_design_space_size_and_config_build():
    assert dse.space_size() > 100_000   # paper: too large for brute force
    rng = np.random.default_rng(1)
    for _ in range(5):
        d = dse.sample_design(rng)
        cfg = dse.design_to_config(d)
        assert cfg.gnn_conv == d["conv"]
        assert cfg.mlp_head.in_dim == d["gnn_out_dim"] * 3
