"""Collective-matmul overlap primitives: equivalence vs plain matmul on a
fake 8-device mesh (subprocess — tests must see 1 device by default)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.distributed.overlap import make_overlapped_ops

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    ag, rs = make_overlapped_ops(mesh, "model")
    rng = np.random.default_rng(0)

    # ag_matmul: Y = all_gather(X_rowsharded) @ W
    x = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \\
            else mesh:
        y = jax.jit(ag)(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)

    # matmul_rs: Y = reduce_scatter(X @ W) with contraction sharded
    x2 = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \\
            else mesh:
        y2 = jax.jit(rs)(x2, w2)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(x2 @ w2),
                               rtol=1e-4, atol=1e-4)
    print("OVERLAP_OK")
""")


def test_collective_matmul_equivalence():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "OVERLAP_OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
