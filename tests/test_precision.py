"""PrecisionPolicy end-to-end: FPX validation, int8 grid <-> fake-quant
equivalence, dtype-polymorphic kernels (kernel == ref == XLA across the
precision grid, incl. empty graphs and all-padding edge blocks), packed
model parity per precision, calibration, DSE/feature plumbing, Project
and serve threading.

Tolerance contract (docs/KERNELS.md):
  fp32  — atol 1e-5 (reassociation only)
  bf16  — kernel-level atol 1e-5 vs the bf16 XLA mirror (identical
          values, fp32 accumulation); model-level <= 5e-2 max-abs vs the
          fp32 oracle on the reduced test config
  int8  — exact grid equivalence vs FPX fake-quant (power-of-two
          scales), kernel-level atol 1e-5 vs the fake-quant XLA mirror;
          model-level error bounded by the calibrated grids' SQNR
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregations as A
from repro.core import convs as C
from repro.core import gnn_model as G
from repro.core import quantization as Q
from repro.data import pipeline as P
from repro.kernels.fused_gather_aggregate.ops import fused_gather_aggregate
from repro.kernels.fused_gather_aggregate.ref import (
    fused_gather_aggregate_ref)
from repro.kernels.segment_aggregate.ops import (
    segment_aggregate as segment_aggregate_op)
from repro.kernels.segment_aggregate.ref import segment_aggregate_ref
from repro.nn import param as prm

PRECISIONS = Q.PRECISIONS

DS = P.GraphDataConfig(avg_nodes=10, max_nodes=64, max_edges=64,
                       node_feat_dim=11, edge_feat_dim=4, seed=5)


def _lp(precision: str) -> Q.LayerPrecision:
    return Q.LayerPrecision(compute=precision, act_fpx=Q.FPX(8, 3))


def _cfg(conv, precision="fp32", task="graph"):
    return G.GNNModelConfig(
        graph_input_feature_dim=11, graph_input_edge_dim=4,
        gnn_hidden_dim=16, gnn_num_layers=2, gnn_output_dim=8,
        gnn_conv=conv, gnn_precision=precision, task=task,
        mlp_head=G.MLPConfig(in_dim=24, out_dim=1, hidden_dim=8,
                             hidden_layers=1) if task == "graph" else None)


def _empty_edge_graph(n=3):
    nf = np.zeros((DS.max_nodes, DS.node_feat_dim), np.float32)
    nf[:n] = np.random.default_rng(7).standard_normal(
        (n, DS.node_feat_dim))
    return P.Graph(node_feat=nf,
                   edge_index=np.full((DS.max_edges, 2), -1, np.int32),
                   edge_feat=np.zeros((DS.max_edges, DS.edge_feat_dim),
                                      np.float32),
                   num_nodes=n, num_edges=0,
                   y=np.zeros((1,), np.float32))


def _packed_batch():
    """5 synthetic graphs + a zero-edge graph packed so the tail edge
    blocks are pure padding — the precision grid must survive both."""
    gs = [P.make_graph(DS, i) for i in range(5)]
    gs.insert(2, _empty_edge_graph())
    batch, k = P.pack_graphs(gs, 128, 256, 8)
    assert k == len(gs)
    return gs, {kk: jnp.asarray(v) for kk, v in batch.items() if kk != "y"}


def _stream(n=37, e=91, f=5, seed=0, pad_every=7):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    if pad_every:
        src[::pad_every] = -1
        dst[::pad_every] = -1
    scale = jnp.asarray(rng.uniform(0.5, 2.0, e), jnp.float32)
    return x, jnp.asarray(src), jnp.asarray(dst), scale


# ------------------------------------------------------ FPX validation --
def test_fpx_rejects_malformed_formats():
    """FPX(4, 8) used to silently yield negative frac bits; malformed
    grids must fail loudly now."""
    with pytest.raises(ValueError):
        Q.FPX(4, 8)           # i > w: negative frac bits
    with pytest.raises(ValueError):
        Q.FPX(0, 1)           # no bits at all
    with pytest.raises(ValueError):
        Q.FPX(-8, -16)
    with pytest.raises(ValueError):
        Q.FPX(8, 0)           # missing the sign bit
    # the paper's formats stay constructible
    assert Q.FPX(32, 16).frac_bits == 16
    assert Q.FPX(16, 10).resolution == 2 ** -6
    assert Q.FPX(8, 8).frac_bits == 0     # i == w is a legal int grid


def test_fpx_for_max_abs_covers_range():
    for max_abs in (0.3, 0.9, 1.0, 1.5, 7.9, 100.0):
        fpx = Q.fpx_for_max_abs(max_abs)
        assert fpx.w == 8
        assert 2.0 ** (fpx.i - 1) >= min(max_abs, 2.0 ** (fpx.w - 1))
    assert Q.fpx_for_max_abs(0.0).i == 1          # degenerate: all-zero
    assert Q.fpx_for_max_abs(float("inf")).i == 1


# ------------------------------------------------- quant error stats ----
def test_quant_error_stats_reduces():
    x = np.random.default_rng(0).standard_normal(512).astype(np.float32)
    fpx = Q.FPX(8, 3)
    stats = Q.quant_error_stats(x, fpx)
    err = np.asarray(Q.quant_error(jnp.asarray(x), fpx))
    assert stats["mean_abs"] == pytest.approx(float(err.mean()), rel=1e-5)
    assert stats["max_abs"] == pytest.approx(float(err.max()), rel=1e-5)
    assert stats["sqnr_db"] > 20.0        # 8-bit grid on unit-ish data
    exact = Q.quant_error_stats(Q.quantize(jnp.asarray(x), fpx), fpx)
    assert exact["max_abs"] == 0.0 and exact["sqnr_db"] == float("inf")


# -------------------------------------------- int8 <-> FPX equivalence --
def test_int8_grid_matches_fpx_fake_quant_exactly():
    """The real int8 representation of an FPX(8, i) grid round-trips to
    exactly the fake-quant values (power-of-two scales are exact)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((257,)) * 10.0, jnp.float32)
    for i in (1, 3, 5, 8):
        fpx = Q.FPX(8, i)
        fake = np.asarray(Q.quantize(x, fpx))
        real = np.asarray(Q.dequantize_int8(Q.quantize_int8(x, fpx), fpx))
        np.testing.assert_array_equal(fake, real)


def test_int8_pallas_path_matches_fake_quant_reference():
    """The true-int8 Pallas sum (int8 tiles + scale folding) reproduces
    the FPX fake-quant XLA reference to fp32 tolerance, and the
    quantized tables themselves match exactly."""
    x, src, dst, _ = _stream()
    lp = _lp("int8")
    pal = np.asarray(A.gather_aggregate(
        "sum", x, src, dst, 37, backend="pallas", edge_block=16,
        node_block=8, precision=lp))
    fake = np.asarray(A.gather_aggregate(
        "sum", Q.quantize(x, lp.act_fpx), src, dst, 37, backend="xla"))
    np.testing.assert_allclose(pal, fake, atol=1e-5)


# ------------------------------------- kernel-level precision parity ----
@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("agg", A.GATHER_AGGREGATIONS)
def test_gather_kernel_ref_xla_agree_across_precisions(agg, precision):
    """kernel == ref == XLA at every precision: the low-precision table
    (bf16 cast / int8 quantized, dequant folded into the scale stream)
    feeds all three the same values."""
    x, src, dst, scale = _stream()
    lp = _lp(precision)
    if precision == "bf16":
        x_k, sc_k = x.astype(jnp.bfloat16), scale
    elif precision == "int8":
        x_k = Q.quantize_int8(x, lp.act_fpx)
        sc_k = scale * lp.act_fpx.resolution
    else:
        x_k, sc_k = x, scale
    got = np.asarray(fused_gather_aggregate(
        x_k, src, dst, None, sc_k, num_segments=37, agg=agg,
        edge_block=16, node_block=8))
    ref = np.asarray(fused_gather_aggregate_ref(
        x_k, src, dst, 37, scale=sc_k, agg=agg))
    xla = np.asarray(A.gather_aggregate(
        agg, x, src, dst, 37, scale=scale, backend="xla", precision=lp))
    np.testing.assert_allclose(got, ref, atol=1e-5)
    np.testing.assert_allclose(got, xla, atol=1e-5)


@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("agg", A.AGGREGATIONS)
def test_segment_backends_agree_across_precisions(agg, precision):
    """Pallas (true low-precision tiles) == XLA (fake-quant mirror) for
    all six aggregations at every precision, on a non-divisible shape
    with interleaved padding."""
    rng = np.random.default_rng(3)
    msg = jnp.asarray(rng.standard_normal((91, 5)), jnp.float32)
    dst = rng.integers(0, 37, 91).astype(np.int32)
    dst[::7] = -1
    lp = _lp(precision)
    xla = np.asarray(A.segment_aggregate(
        agg, msg, jnp.asarray(dst), 37, backend="xla", precision=lp))
    pal = np.asarray(A.segment_aggregate(
        agg, msg, jnp.asarray(dst), 37, backend="pallas", edge_block=16,
        node_block=8, precision=lp))
    np.testing.assert_allclose(pal, xla, atol=1e-5)


@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_segment_kernel_accepts_low_precision_tiles_directly(precision):
    """The kernel itself is dtype-polymorphic: bf16/int8 message blocks
    go through pallas_call at their storage width and match ref.py."""
    rng = np.random.default_rng(4)
    msg32 = jnp.asarray(rng.standard_normal((50, 3)), jnp.float32)
    msg = msg32.astype(jnp.bfloat16) if precision == "bf16" \
        else Q.quantize_int8(msg32, Q.FPX(8, 3))
    dst = jnp.asarray(rng.integers(0, 11, 50), jnp.int32)
    got = np.asarray(segment_aggregate_op(
        msg, dst, num_segments=11, agg="sum", edge_block=16,
        node_block=8))
    ref = np.asarray(segment_aggregate_ref(msg, dst, 11, agg="sum"))
    np.testing.assert_allclose(got, ref, atol=1e-5)
    assert msg.dtype == (jnp.bfloat16 if precision == "bf16" else jnp.int8)


@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("agg", A.GATHER_AGGREGATIONS)
def test_all_padding_edge_blocks_every_precision(agg, precision):
    """Edge blocks made entirely of padding contribute nothing and
    isolated nodes zero-fill, at every precision and on both backends."""
    x, _, _, _ = _stream(n=12, e=64, f=3, pad_every=0)
    src = np.asarray(_stream(n=12, e=64, f=3, pad_every=0)[1]).copy()
    dst = src.copy()
    src[16:] = -1              # blocks 2..4 of edge_block=16: all padding
    dst[16:] = -1
    dst[:16] = np.arange(16) % 5          # nodes 5..11 isolated
    lp = _lp(precision)
    pal = np.asarray(A.gather_aggregate(
        agg, x, jnp.asarray(src), jnp.asarray(dst), 12,
        backend="pallas", edge_block=16, node_block=8, precision=lp))
    xla = np.asarray(A.gather_aggregate(
        agg, x, jnp.asarray(src), jnp.asarray(dst), 12, backend="xla",
        precision=lp))
    np.testing.assert_allclose(pal, xla, atol=1e-5)
    np.testing.assert_allclose(pal[5:], 0.0, atol=1e-6)


# ---------------------------------------- model-level precision parity --
@pytest.mark.parametrize("conv", C.CONV_TYPES)
def test_bf16_policy_within_documented_tolerance(conv):
    """apply_packed under the bf16 policy vs the fp32 oracle: <= 5e-2
    max-abs on the reduced config (the KERNELS.md tolerance table)."""
    cfg = _cfg(conv)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    _, jb = _packed_batch()
    ref = np.asarray(jax.jit(
        lambda p, b: G.apply_packed(p, cfg, b))(params, jb))
    pol = G.resolve_policy(cfg, "bf16")
    got = np.asarray(jax.jit(
        lambda p, b: G.apply_packed(p, cfg, b, None, pol))(params, jb))
    assert float(np.max(np.abs(got - ref))) < 5e-2, conv


@pytest.mark.parametrize("precision", ["bf16", "int8"])
@pytest.mark.parametrize("conv", C.CONV_TYPES)
def test_packed_backend_parity_per_precision(conv, precision):
    """XLA vs Pallas trace of the same low-precision policy agree to
    fp32 tolerance for every conv — including the empty-edge graph and
    the all-padding tail blocks of the packed batch."""
    cfg = _cfg(conv)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(1))
    _, jb = _packed_batch()
    pol = G.calibrated_policy(params, cfg, jb, precision)
    with A.backend_scope("xla"):
        ref = np.asarray(jax.jit(lambda p, b: G.apply_packed(
            p, cfg, b, None, pol))(params, jb))
    with A.backend_scope("pallas", 32, 16):
        got = np.asarray(jax.jit(lambda p, b: G.apply_packed(
            p, cfg, b, None, pol))(params, jb))
    assert float(np.max(np.abs(got - ref))) < 1e-4, (conv, precision)


@pytest.mark.parametrize("precision", PRECISIONS)
def test_packed_matches_padded_oracle_per_precision(precision):
    """The packed and padded paths resolve the policy identically, so
    per-graph outputs agree at every precision (same-precision parity is
    tight even for int8 — both paths quantize identically)."""
    cfg = _cfg("gcn", precision=precision)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(2))
    gs, jb = _packed_batch()
    packed = np.asarray(jax.jit(
        lambda p, b: G.apply_packed(p, cfg, b))(params, jb))
    pad_fn = jax.jit(lambda p, el: G.apply(p, cfg, el))
    for i, g in enumerate(gs):
        el = {"node_feat": jnp.asarray(g.node_feat),
              "edge_index": jnp.asarray(g.edge_index),
              "edge_feat": jnp.asarray(g.edge_feat),
              "num_nodes": jnp.int32(g.num_nodes)}
        want = np.asarray(pad_fn(params, el))
        np.testing.assert_allclose(packed[i], want, atol=1e-4)


def test_empty_edge_graph_every_precision():
    """A packed batch holding a zero-edge graph stays finite and matches
    the fp32 shape at every precision (isolated nodes zero-fill)."""
    cfg0 = _cfg("sage")
    params = prm.materialize(G.model_plan(cfg0), jax.random.key(3))
    _, jb = _packed_batch()
    for precision in PRECISIONS:
        cfg = dataclasses.replace(cfg0, gnn_precision=precision)
        out = np.asarray(jax.jit(
            lambda p, b: G.apply_packed(p, cfg, b))(params, jb))
        assert np.isfinite(out).all(), precision


# ---------------------------------------------------- calibration -------
def test_calibration_fits_grids_to_ranges():
    cfg = _cfg("gcn")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(4))
    _, jb = _packed_batch()
    r = G.activation_ranges(params, cfg, jb)
    assert len(r["acts"]) == cfg.gnn_num_layers
    assert all(v > 0 for v in r["acts"]) and r["head"] > 0
    pol = G.calibrated_policy(params, cfg, jb, "int8")
    assert pol.calibrated and not pol.needs_calibration
    for i, lp in enumerate(pol.layers):
        assert 2.0 ** (lp.act_fpx.i - 1) >= min(r["acts"][i], 128.0)
    # calibrated grids beat the uncalibrated default on output error
    ref = np.asarray(jax.jit(
        lambda p, b: G.apply_packed(p, cfg, b))(params, jb))
    out = np.asarray(jax.jit(
        lambda p, b: G.apply_packed(p, cfg, b, None, pol))(params, jb))
    assert Q.error_stats(out, ref)["sqnr_db"] > 10.0


def test_resolve_policy_shapes_and_validation():
    pol = Q.resolve_policy("bf16", 3)
    assert len(pol.layers) == 3 and pol.name == "bf16"
    assert pol.layer(7).compute == "bf16"     # clamps past the last layer
    assert Q.resolve_policy(None, 2).is_fp32
    assert Q.resolve_policy(pol, 5).layers != pol.layers  # re-padded
    with pytest.raises(ValueError):
        Q.resolve_policy("fp8", 2)
    with pytest.raises(ValueError):
        Q.LayerPrecision(compute="int4")
    assert Q.LayerPrecision(compute="int8").accum == "int32"
    assert Q.LayerPrecision(compute="bf16").accum == "fp32"
    assert pol.compute_bytes == 2.0


def test_ste_gradients_flow_through_quantized_path():
    """Fake-quant is piecewise-constant, so without the straight-through
    estimator an int8 (or legacy fixed) datapath trains with silent
    all-zero gradients. quantize must keep the exact grid forward and
    the identity backward."""
    fpx = Q.FPX(8, 3)
    x = jnp.asarray([0.3, -1.2, 3.9], jnp.float32)
    grad = jax.grad(lambda v: jnp.sum(Q.quantize(v, fpx)))(x)
    np.testing.assert_allclose(np.asarray(grad), 1.0)
    # forward stays bit-exact on the grid (the int8 equivalence relies
    # on it)
    np.testing.assert_array_equal(
        np.asarray(Q.quantize(x, fpx)),
        np.asarray(Q.dequantize_int8(Q.quantize_int8(x, fpx), fpx)))
    # end-to-end: the packed loss under an int8 config produces nonzero
    # conv-weight gradients
    gs = [P.make_graph(DS, i) for i in range(5)]
    batch, _ = P.pack_graphs(gs, 128, 256, 8)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    cfg = _cfg("gcn", precision="int8")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(6))
    grads = jax.grad(lambda p: G.mse_loss_packed(p, cfg, jb))(params)
    gmax = max(float(jnp.max(jnp.abs(a)))
               for a in jax.tree_util.tree_leaves(grads))
    assert gmax > 0.0


# ------------------------------------------------- cost-model plumbing --
def test_dataflow_cost_scales_with_byte_width():
    """The edge-stream term of the dataflow cost shrinks with storage
    width; the matmul term does not."""
    full = C.dataflow_cost(64, 16, 2.0, msg_bytes=4.0)
    half = C.dataflow_cost(64, 16, 2.0, msg_bytes=2.0)
    assert half["aggregate_first"] < full["aggregate_first"]
    assert half["transform_first"] < full["transform_first"]
    # the byte-dependent stream term scales exactly with bytes; the
    # gather-compute term (gather_compute_flops, byte-invariant fp32
    # work) does not — msg_bytes=0 isolates it
    zero = C.dataflow_cost(64, 16, 2.0, msg_bytes=0.0)
    gap_full = full["aggregate_first"] - full["transform_first"]
    gap_half = half["aggregate_first"] - half["transform_first"]
    gap_zero = zero["aggregate_first"] - zero["transform_first"]
    assert gap_half - gap_zero == pytest.approx((gap_full - gap_zero) / 2.0)
    # the choice itself is width-invariant (both sides scale equally)
    cc = C.ConvConfig(64, 16, conv="gcn", precision=_lp("int8"))
    assert C.resolve_dataflow(cc) == "transform_first"


def test_dse_and_features_carry_precision():
    """precision is sampled, reaches the model config and fpx_bits, and
    featurizes; old databases without the key still featurize as fp32."""
    from repro.core import dse
    from repro.core import perf_model as PM
    rng = np.random.default_rng(0)
    ds = [dse.sample_design(rng) for _ in range(48)]
    assert all(d["precision"] in dse.SPACE["precision"] for d in ds)
    assert len({d["precision"] for d in ds}) > 1
    d = next(d for d in ds if d["precision"] == "int8")
    assert d["fpx_bits"] == 8
    assert dse.design_to_config(d).gnn_precision == "int8"
    v = PM.features(d)
    assert len(v) == len(PM.FEATURE_NAMES)
    assert v[PM.FEATURE_NAMES.index("precision_int8")] == 1.0
    assert v[PM.FEATURE_NAMES.index("precision_bf16")] == 0.0
    assert v[PM.FEATURE_NAMES.index("compute_bytes")] == 1.0
    legacy = dict(d)
    legacy.pop("precision")
    w = PM.features(legacy)
    assert len(w) == len(PM.FEATURE_NAMES)
    assert w[PM.FEATURE_NAMES.index("precision_int8")] == 0.0
    assert w[PM.FEATURE_NAMES.index("compute_bytes")] == 4.0


# ------------------------------------------------ Project + serve -------
@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_project_resolves_policy_end_to_end(tmp_path, precision):
    """Project(precision=...) -> packed inference -> testbench report
    with quant-error stats; the resolved (calibrated) policy lands in
    config.json and the synthesis report prices the byte width."""
    from repro.core.project import Project
    cfg = _cfg("gcn")
    proj = Project("prec", cfg, "dse", str(tmp_path), max_nodes=64,
                   max_edges=64, batch_graphs=8, precision=precision)
    proj.init_params()
    proj.gen_testbench(num_graphs=8)
    tb = proj.build_and_run_testbench()
    assert tb["precision"] == precision
    assert "quant_error" not in tb or precision != "bf16" \
        or True  # bf16 reports output error too
    if precision == "int8":
        assert proj.policy.calibrated
        assert tb["quant_error"]["weights"]["max_abs"] >= 0.0
    assert tb["quant_error"]["output"]["sqnr_db"] > 10.0
    assert tb["packed"]["n_graphs"] > 0
    with open(tmp_path / "config.json") as f:
        rec = json.load(f)["precision"]
    assert rec["name"] == precision
    assert rec["layers"][0]["compute"] == precision
    assert rec["compute_bytes"] == (2.0 if precision == "bf16" else 1.0)
    rep = proj.run_synthesis()
    assert rep["precision"] == precision
    assert rep["packed"]["compute_bytes"] == rec["compute_bytes"]


def test_project_precision_shrinks_modeled_bytes(tmp_path):
    """Same design, lower precision -> fewer effective bytes and no
    worse modeled packed latency (the DSE objective sees the knob)."""
    from repro.core.project import Project
    cfg = _cfg("gcn")

    def rep(precision):
        proj = Project(f"w_{precision}", cfg, "dse", str(tmp_path),
                       max_nodes=64, max_edges=64, batch_graphs=8,
                       precision=precision)
        proj.gen_hw_model()
        return proj.run_synthesis()

    r32, r8 = rep("fp32"), rep("int8")
    assert r8["packed"]["bytes_accessed"] < r32["packed"]["bytes_accessed"]
    assert r8["packed"]["latency_s"] <= r32["packed"]["latency_s"]


@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_serve_queue_under_precision_policy(precision):
    """The serve path (drain_gnn_queue with a policy-baked program +
    padded fallback) answers every request at low precision within
    tolerance of the fp32 program."""
    from repro.launch.serve import drain_gnn_queue
    cfg = _cfg("gcn")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(5))
    queue = [P.make_graph(DS, i) for i in range(6)]
    batch, _ = P.pack_graphs(queue, 128, 256, 8)
    pol = G.calibrated_policy(params, cfg, G.packed_to_device(batch),
                              precision)
    fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b, None, pol))
    fb = jax.jit(lambda p, el: G.apply(p, cfg, el, None, pol))
    outs, stats = drain_gnn_queue(fn, params, queue, 128, 256, 8, fb)
    assert stats["served"] == len(queue) and stats["dropped"] == 0
    ref_fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))
    ref = np.asarray(ref_fn(params, G.packed_to_device(batch)))
    got = np.asarray(outs[0])
    k = int(batch["num_graphs"])
    tol = 5e-2 if precision == "bf16" else 5e-1
    assert float(np.max(np.abs(got[:k] - ref[:k]))) < tol
