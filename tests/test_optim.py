"""Optimizer + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.param import ParamSpec, materialize
from repro.optim import adamw
from repro.optim import compress


def test_adamw_minimizes_quadratic():
    cfg = adamw.OptConfig(peak_lr=0.1, warmup_steps=5, decay_steps=200,
                          weight_decay=0.0)
    plan = {"w": ParamSpec((8,), jnp.float32, (None,))}
    params = materialize(plan, jax.random.key(0))
    state = materialize(adamw.opt_plan(plan, cfg), jax.random.key(1))
    target = jnp.arange(8.0)

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(
            lambda q: jnp.sum((q["w"] - target) ** 2))(p)
        p, s, m = adamw.apply_updates(cfg, p, g, s)
        return p, s, loss

    losses = []
    for _ in range(150):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 1e-2


def test_schedule_warmup_and_decay():
    cfg = adamw.OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    lr5 = float(adamw.schedule(cfg, jnp.int32(5)))
    lr10 = float(adamw.schedule(cfg, jnp.int32(10)))
    lr100 = float(adamw.schedule(cfg, jnp.int32(100)))
    assert 0.4 < lr5 < 0.6
    assert abs(lr10 - 1.0) < 1e-5
    assert abs(lr100 - 0.1) < 1e-5


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    got = float(adamw.global_norm(clipped))
    assert abs(got - 1.0) < 1e-4
    assert abs(float(norm) - np.sqrt(800.0)) < 1e-2


def test_bf16_moments_roundtrip():
    cfg = adamw.OptConfig(moment_dtype="bfloat16")
    plan = {"w": ParamSpec((4,), jnp.float32, (None,))}
    state = materialize(adamw.opt_plan(plan, cfg), jax.random.key(0))
    assert state["m"]["w"].dtype == jnp.bfloat16


# ------------------------------------------------------ grad compression --
def test_int8_quantize_roundtrip_error():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, s = compress.quantize_int8(g)
    deq = compress.dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) / 2 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the *accumulated* compressed gradient tracks
    the accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal((64,)) * 1e-3, jnp.float32)
    errors = {"g": jnp.zeros((64,), jnp.float32)}
    acc = jnp.zeros((64,))
    for _ in range(50):
        q, s, errors_new = compress.ef_quantize({"g": g_true}, errors)
        deq = compress.ef_restore(q, s)
        acc = acc + deq["g"]
        errors = errors_new
    # mean compressed gradient ~= true gradient
    np.testing.assert_allclose(acc / 50, g_true, atol=2e-5)


def test_compressed_sgd_converges():
    """SGD on a quadratic with int8+EF compression still converges."""
    w = jnp.ones((16,)) * 5.0
    err = {"w": jnp.zeros((16,))}
    for _ in range(300):
        g = {"w": 2 * w}
        q, s, err = compress.ef_quantize(g, err)
        deq = compress.ef_restore(q, s)
        w = w - 0.05 * deq["w"]
    assert float(jnp.max(jnp.abs(w))) < 1e-2
