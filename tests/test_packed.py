"""Packed GraphBatch IR: packed-vs-padded equivalence for every conv type
and aggregation (including isolated nodes and empty-edge graphs), packing
invariants, budget overflow handling, and deterministic bucketing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregations as A
from repro.core import gnn_model as G
from repro.core.convs import CONV_TYPES
from repro.core.pooling import POOLINGS, global_pool, segment_global_pool
from repro.data import pipeline as P
from repro.nn import param as prm

DS = P.GraphDataConfig(avg_nodes=10, max_nodes=64, max_edges=64,
                       node_feat_dim=11, edge_feat_dim=4, seed=5)


def _cfg(conv, task="graph"):
    return G.GNNModelConfig(
        graph_input_feature_dim=11, graph_input_edge_dim=4,
        gnn_hidden_dim=16, gnn_num_layers=2, gnn_output_dim=8,
        gnn_conv=conv, task=task,
        mlp_head=G.MLPConfig(in_dim=24, out_dim=1, hidden_dim=8,
                             hidden_layers=1) if task == "graph" else None)


def _empty_edge_graph(n=3):
    """A graph whose nodes are all isolated (num_edges == 0)."""
    nf = np.zeros((DS.max_nodes, DS.node_feat_dim), np.float32)
    nf[:n] = np.random.default_rng(7).standard_normal(
        (n, DS.node_feat_dim))
    return P.Graph(node_feat=nf,
                   edge_index=np.full((DS.max_edges, 2), -1, np.int32),
                   edge_feat=np.zeros((DS.max_edges, DS.edge_feat_dim),
                                      np.float32),
                   num_nodes=n, num_edges=0,
                   y=np.zeros((1,), np.float32))


def _graphs():
    gs = [P.make_graph(DS, i) for i in range(5)]
    gs.insert(2, _empty_edge_graph())        # isolated nodes, zero edges
    return gs


def _el(g):
    return {"node_feat": jnp.asarray(g.node_feat),
            "edge_index": jnp.asarray(g.edge_index),
            "edge_feat": jnp.asarray(g.edge_feat),
            "num_nodes": jnp.int32(g.num_nodes)}


def _pack(graphs, max_graphs=8):
    batch, k = P.pack_graphs(graphs, 128, 256, max_graphs)
    assert k == len(graphs)
    return {kk: jnp.asarray(v) for kk, v in batch.items() if kk != "y"}


# -------------------------------------------------- model equivalence ---
@pytest.mark.parametrize("conv", CONV_TYPES)
def test_apply_packed_matches_apply(conv):
    """One jitted packed program == the per-graph padded oracle, for every
    conv type, including an empty-edge graph mid-batch."""
    cfg = _cfg(conv)
    params = prm.materialize(G.model_plan(cfg), jax.random.key(0))
    graphs = _graphs()
    jb = _pack(graphs)
    packed_fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))
    loop_fn = jax.jit(lambda p, el: G.apply(p, cfg, el))
    out = np.asarray(packed_fn(params, jb))
    for i, g in enumerate(graphs):
        ref = np.asarray(loop_fn(params, _el(g)))
        assert float(np.mean(np.abs(out[i] - ref))) < 1e-4, (conv, i)


@pytest.mark.parametrize("conv", CONV_TYPES)
def test_apply_packed_node_task(conv):
    cfg = _cfg(conv, task="node")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(1))
    graphs = _graphs()
    jb = _pack(graphs)
    packed_fn = jax.jit(lambda p, b: G.apply_packed(p, cfg, b))
    loop_fn = jax.jit(lambda p, el: G.apply(p, cfg, el))
    out = np.asarray(packed_fn(params, jb))
    off = 0
    for g in graphs:
        ref = np.asarray(loop_fn(params, _el(g)))[:g.num_nodes]
        got = out[off:off + g.num_nodes]
        assert float(np.mean(np.abs(got - ref))) < 1e-4
        off += g.num_nodes


def test_mse_loss_packed_matches_per_graph():
    cfg = _cfg("gcn")
    params = prm.materialize(G.model_plan(cfg), jax.random.key(2))
    graphs = _graphs()
    batch, k = P.pack_graphs(graphs, 128, 256, 8)
    jb = {kk: jnp.asarray(v) for kk, v in batch.items()}
    loss = float(G.mse_loss_packed(params, cfg, jb))
    per = [float(jnp.mean(jnp.square(
        G.apply(params, cfg, _el(g)) - jnp.asarray(g.y))))
        for g in graphs]
    np.testing.assert_allclose(loss, np.mean(per), rtol=1e-4)


# --------------------------------------------- aggregation equivalence --
@pytest.mark.parametrize("agg", A.AGGREGATIONS)
def test_packed_segment_aggregate_matches_per_graph(agg):
    """Segment aggregation over the packed edge buffer == per-graph
    aggregation, for all six aggregations."""
    graphs = _graphs()
    batch, _ = P.pack_graphs(graphs, 128, 256, 8)
    rng = np.random.default_rng(0)
    msgs = rng.standard_normal((256, 3)).astype(np.float32)
    dst = batch["edge_index"][:, 1]
    valid = batch["edge_index"][:, 0] >= 0
    out = np.asarray(A.segment_aggregate(
        agg, jnp.asarray(msgs), jnp.asarray(np.maximum(dst, 0)), 128,
        jnp.asarray(valid)))
    off_n = off_e = 0
    for g in graphs:
        for v in range(g.num_nodes):
            sel = (batch["edge_index"][:, 1] == off_n + v) & valid
            if not sel.any():
                np.testing.assert_allclose(out[off_n + v], 0.0, atol=1e-6)
                continue
            want = np.asarray(A.aggregate_stream(
                agg, jnp.asarray(msgs[sel])))
            np.testing.assert_allclose(out[off_n + v], want, rtol=1e-3,
                                       atol=1e-3)
        off_n += g.num_nodes
        off_e += g.num_edges


@pytest.mark.parametrize("kind", POOLINGS)
def test_segment_pooling_matches_dense(kind):
    graphs = _graphs()
    batch, _ = P.pack_graphs(graphs, 128, 256, 8)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((128, 6)).astype(np.float32)
    gid = jnp.asarray(batch["node_graph_id"])
    got = np.asarray(segment_global_pool(kind, jnp.asarray(x), gid, 8))
    off = 0
    for i, g in enumerate(graphs):
        xg = x[off:off + g.num_nodes]
        mask = jnp.ones((g.num_nodes,), bool)
        want = np.asarray(global_pool(kind, jnp.asarray(xg), mask))
        np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-5)
        off += g.num_nodes
    # padding rows (beyond the packed graphs) pool to zero
    np.testing.assert_allclose(got[len(graphs):], 0.0, atol=1e-6)


def test_segment_counts_match_graph_num_nodes():
    """segment_counts over the packed node/edge ids reproduces the
    per-graph counts recorded at pack time (padding -> overflow bucket)."""
    graphs = _graphs()
    batch, k = P.pack_graphs(graphs, 128, 256, 8)
    node_counts = np.asarray(A.segment_counts(
        jnp.asarray(batch["node_graph_id"]), 8))
    assert node_counts.dtype == np.float32
    np.testing.assert_array_equal(node_counts,
                                  batch["graph_num_nodes"].astype(np.float32))
    edge_counts = np.asarray(A.segment_counts(
        jnp.asarray(batch["edge_graph_id"]), 8))
    np.testing.assert_array_equal(
        edge_counts, np.float32([g.num_edges for g in graphs] + [0, 0]))
    # explicit valid mask routes masked slots into the dropped bucket
    masked = np.asarray(A.segment_counts(
        jnp.asarray(batch["node_graph_id"]), 8,
        valid=jnp.asarray(batch["node_graph_id"] != 0)))
    assert masked[0] == 0.0


# ------------------------------------------------------- pack invariants --
def test_pack_dataset_partitions_and_respects_budgets():
    """Property test: over many budget settings, every graph lands in
    exactly one batch or in ``dropped``, and no batch overflows."""
    rng = np.random.default_rng(0)
    cfg = P.GraphDataConfig(avg_nodes=14, max_nodes=80, max_edges=120,
                            node_feat_dim=5, edge_feat_dim=2, seed=3)
    graphs = [P.make_graph(cfg, i) for i in range(40)]
    for trial in range(12):
        nb = int(rng.integers(8, 120))
        eb = int(rng.integers(8, 200))
        mg = int(rng.integers(1, 12))
        batches, dropped = P.pack_dataset(graphs, nb, eb, mg)
        n_packed = sum(int(b["num_graphs"]) for b in batches)
        assert n_packed + len(dropped) == len(graphs)
        for g in dropped:     # only graphs that can never fit are dropped
            assert g.num_nodes > nb or g.num_edges > eb
        for b in batches:
            k = int(b["num_graphs"])
            assert 1 <= k <= mg
            node_valid = b["node_graph_id"] < mg
            edge_valid = b["edge_index"][:, 0] >= 0
            assert int(node_valid.sum()) <= nb
            assert int(edge_valid.sum()) <= eb
            # edges reference valid nodes of their own graph
            src = b["edge_index"][edge_valid]
            assert (b["node_graph_id"][src[:, 0]]
                    == b["edge_graph_id"][edge_valid]).all()
            assert (b["node_graph_id"][src[:, 1]]
                    == b["edge_graph_id"][edge_valid]).all()
            # graph ids are contiguous 0..k-1 in packing order
            ids = b["node_graph_id"][node_valid]
            assert (np.diff(ids) >= 0).all() and set(ids) == set(range(k))


def test_pack_graphs_raises_on_oversize_first():
    g = P.make_graph(DS, 0)
    with pytest.raises(ValueError):
        P.pack_graphs([g], node_budget=2, edge_budget=2, max_graphs=4)


def test_pack_graphs_stops_at_budget():
    graphs = [P.make_graph(DS, i) for i in range(10)]
    nb = graphs[0].num_nodes + graphs[1].num_nodes
    batch, k = P.pack_graphs(graphs, nb, 10_000, 10)
    assert k == 2                      # third graph would overflow nodes
    assert int((batch["node_graph_id"] < 10).sum()) <= nb


def test_graph_batch_packed_deterministic():
    b1 = P.graph_batch_packed(DS, step=3, node_budget=256,
                              edge_budget=512, max_graphs=8)
    b2 = P.graph_batch_packed(DS, step=3, node_budget=256,
                              edge_budget=512, max_graphs=8)
    np.testing.assert_array_equal(b1["node_feat"], b2["node_feat"])
    np.testing.assert_array_equal(b1["edge_index"], b2["edge_index"])
    b3 = P.graph_batch_packed(DS, step=4, node_budget=256,
                              edge_budget=512, max_graphs=8)
    assert not np.array_equal(b1["node_feat"], b3["node_feat"])


def test_size_budget_rule():
    assert P.size_budget(32, 18) % 8 == 0
    assert P.size_budget(32, 18) >= 32 * 18      # slack over the mean
    assert P.size_budget(1, 1) >= 1
