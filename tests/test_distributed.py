"""Distributed machinery: sharding rules, HLO collective/dot parsing, and
a tiny-mesh dry-run in a subprocess (8 fake devices)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed import hlo as H
from repro.distributed.sharding import (DEFAULT_RULES, FSDP_RULES,
                                        auto_preset, resolve_axis, spec_for)


class _FakeMesh:
    axis_names = ("data", "model")
    devices = np.empty((4, 8))


def test_resolve_axis_divisibility_fallback():
    mesh = _FakeMesh()
    assert resolve_axis("heads", {"heads": ("model",)}, mesh, 32) == \
        ("model",)
    # 12 heads % 8 -> replicate
    assert resolve_axis("heads", {"heads": ("model",)}, mesh, 12) == ()
    # multi-axis: keeps prefix that divides
    assert resolve_axis("batch", {"batch": ("data", "model")}, mesh, 8) == \
        ("data",)


def test_spec_for_no_axis_reuse():
    mesh = _FakeMesh()
    spec = spec_for(("batch", "seq", "embed"), (8, 128, 64), mesh,
                    {"batch": ("data",), "seq": (), "embed": ("data",)})
    assert spec[0] == "data" and spec[2] is None   # embed dropped (used)


def test_auto_preset_table():
    from repro.configs.registry import get_config
    qwen = get_config("qwen3-8b")
    dsv2 = get_config("deepseek-v2-236b")
    jamba = get_config("jamba-1.5-large-398b")
    assert auto_preset(qwen, "train", False) == "fsdp"
    assert auto_preset(dsv2, "train", False) == "fsdp_tp"
    assert auto_preset(jamba, "train", False) == "fsdp_tp_nosp"
    assert auto_preset(qwen, "train", True) == "fsdp_tp"
    assert auto_preset(qwen, "prefill", False) == "fsdp_seq"
    assert auto_preset(dsv2, "prefill", False) == "fsdp_tp"  # MLA
    assert auto_preset(qwen, "decode", False) == "fsdp_tp"


# ------------------------------------------------------------ HLO parser --
HLO_SAMPLE = """
HloModule test

%body (p: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
  %ag = f32[16,64]{1,0} all-gather(%x), channel_id=1, replica_groups=[4,8]<=[32], dimensions={0}
  %ar = f32[8,8]{1,0} all-reduce(%y), channel_id=2, replica_groups=[2,16]<=[32], to_apply=%add
  ROOT %t = (s32[], f32[16,64]) tuple(%i, %ag)
}

ENTRY %main (a: f32[16,64]) -> f32[16,64] {
  %w = (s32[], f32[16,64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %rs = f32[4,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[8,4]<=[32], dimensions={0}
  ROOT %out = f32[16,64] get-tuple-element(%w), index=1
}
"""


def test_collective_stats_trip_scaling():
    st = H.collective_stats(HLO_SAMPLE)
    # all-gather: 16*64*4 = 4096B, x10 trips
    assert st["all-gather"]["bytes"] == 4096 * 10
    assert st["all-gather"]["count"] == 10
    # all-reduce: 2 * 8*8*4 = 512B x10
    assert st["all-reduce"]["bytes"] == 512 * 10
    # reduce-scatter: result 4*64*4=1024B x (group 4 - 1)
    assert st["reduce-scatter"]["bytes"] == 1024 * 3
    assert st["total_bytes"] == 4096 * 10 + 512 * 10 + 1024 * 3


def test_shape_bytes():
    assert H.shape_bytes("bf16[4,8]") == 64
    assert H.shape_bytes("f32[10] s8[3]") == 43
    assert H.shape_bytes("pred[7]") == 7
    assert H.shape_bytes("(f32[2,2], bf16[4])") == 24


DOT_SAMPLE = """
HloModule m

ENTRY %main (a: f32[8,16]) -> f32[8,32] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,32]{1,0} parameter(1)
  ROOT %dot = f32[8,32]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_dot_stats():
    st = H.dot_stats(DOT_SAMPLE)
    assert st["flops"] == 2 * 8 * 32 * 16
    assert st["count"] == 1
    # bytes: a 512 + b 2048 + out 1024
    assert st["bytes"] == 512 + 2048 + 1024


# -------------------------------------------------- tiny dry-run e2e -----
TINY_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.launch import steps as S
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    cfg = get_config("qwen3-8b", reduced=True)
    b = S.make_train_step(cfg, mesh, seq=64, batch=8)
    compiled = b.lower().compile()
    assert compiled.cost_analysis() is not None
    print("TINY_DRYRUN_OK")
""")


def test_tiny_mesh_dryrun_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", TINY_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "TINY_DRYRUN_OK" in out.stdout, out.stderr[-2000:]


GNN_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs.gnn import config
    from repro.launch import steps as S
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    cfg = config("gcn", reduced=True)
    b = S.make_gnn_train_step(cfg, mesh, batch=16)
    compiled = b.lower().compile()
    assert compiled.cost_analysis() is not None
    print("GNN_DRYRUN_OK")
""")


def test_gnn_distributed_train_step_subprocess():
    """The paper's workloads go through the same distributed launcher."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", GNN_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "GNN_DRYRUN_OK" in out.stdout, out.stderr[-2000:]
