"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the Pallas kernel body on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.gnn_aggregate.ops import gnn_aggregate
from repro.kernels.gnn_aggregate.ref import gnn_aggregate_ref, neighbor_table
from repro.kernels.tiled_linear.ops import tiled_matmul, \
    blocks_from_parallelism
from repro.kernels.tiled_linear.ref import tiled_matmul_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("agg", ["sum", "mean", "min", "max", "var", "std"])
@pytest.mark.parametrize("n,f,k", [(64, 16, 4), (200, 64, 8), (37, 33, 3)])
def test_gnn_aggregate_matches_ref(agg, n, f, k):
    x = jnp.asarray(RNG.standard_normal((n, f)), jnp.float32)
    ei = RNG.integers(0, n, (3 * n, 2)).astype(np.int32)
    nbr = jnp.asarray(neighbor_table(ei, n, k))
    got = gnn_aggregate(x, nbr, agg=agg, block_nodes=32)
    want = gnn_aggregate_ref(x, nbr, agg=agg)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_gnn_aggregate_isolated_nodes_zero():
    x = jnp.ones((8, 4), jnp.float32)
    nbr = jnp.full((8, 3), -1, jnp.int32)   # no neighbors at all
    for agg in ("sum", "mean", "min", "max", "var"):
        out = gnn_aggregate(x, nbr, agg=agg, block_nodes=8)
        # var/std clamp at 1e-12 to keep sqrt grads finite
        np.testing.assert_allclose(out, 0.0, atol=1e-11)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 64, 64, 64),
    (130, 200, 70, 64, 64, 64),     # ragged / padded path
    (32, 512, 96, 32, 32, 128),
])
def test_tiled_matmul_matches_ref(dtype, m, k, n, bm, bn, bk):
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype)
    got = tiled_matmul(a, b, block_m=bm, block_n=bn, block_k=bk)
    want = tiled_matmul_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_blocks_from_parallelism_aligned():
    for p_in in (1, 2, 4, 8, 16):
        for p_out in (1, 2, 4, 8):
            bk, bn = blocks_from_parallelism(p_in, p_out)
            assert bk % 64 == 0 and bn % 64 == 0
            assert bk >= 128 and bn >= 128


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bh,s,d", [(4, 128, 32), (2, 256, 64), (1, 64, 16)])
def test_flash_attention_matches_ref(causal, bh, s, d):
    q = jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_4d_bf16():
    q = jnp.asarray(RNG.standard_normal((2, 3, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, 3, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, 3, 64, 32)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=32, block_k=32)
    want = attention_ref(q.reshape(6, 64, 32), k.reshape(6, 64, 32),
                         v.reshape(6, 64, 32)).reshape(2, 3, 64, 32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)
