"""GNN model semantics: conv correctness on hand-computed graphs,
permutation equivariance, pooling invariants, fixed-point testbench MAE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gnn import config, DATASETS
from repro.core import convs as C
from repro.core import gnn_model as G
from repro.core import quantization as Q
from repro.core.pooling import global_pool, global_pooling
from repro.data.pipeline import GraphDataConfig, make_graph, graph_batch
from repro.nn import param as prm

RNG = np.random.default_rng(3)


def _tiny_graph(n=4, f=3, edges=((0, 1), (1, 0), (1, 2), (2, 1))):
    max_n, max_e = 8, 8
    nf = np.zeros((max_n, f), np.float32)
    nf[:n] = RNG.standard_normal((n, f))
    ei = np.full((max_e, 2), -1, np.int32)
    for i, (s, d) in enumerate(edges):
        ei[i] = (s, d)
    return {"node_feat": jnp.asarray(nf),
            "edge_index": jnp.asarray(ei),
            "edge_feat": jnp.zeros((max_e, 2), jnp.float32),
            "num_nodes": jnp.int32(n)}


def test_sage_matches_manual():
    """x' = W1 x + W2 mean(neighbors) — checked by hand on a path graph."""
    el = _tiny_graph()
    cfg = C.ConvConfig(in_dim=3, out_dim=4, conv="sage")
    params = prm.materialize(C.conv_plan(cfg), jax.random.key(0))
    g, x, mask = G.graph_inputs(el)
    out = C.conv_apply(params, g, x, cfg)
    w_self, b = params["w_self"]["w"], params["w_self"]["b"]
    w_n = params["w_neigh"]["w"]
    x_np = np.asarray(x)
    # node 1 has neighbors {0, 2}
    want1 = x_np[1] @ w_self + b + ((x_np[0] + x_np[2]) / 2) @ w_n
    np.testing.assert_allclose(np.asarray(out)[1], want1, rtol=2e-3,
                               atol=2e-3)
    # node 3 is isolated: neighbor term is zero
    want3 = x_np[3] @ w_self + b
    np.testing.assert_allclose(np.asarray(out)[3], want3, rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("conv", ["gcn", "sage", "gin", "pna"])
def test_conv_permutation_equivariance(conv):
    """Relabeling nodes permutes the output rows identically."""
    n, f = 5, 4
    el = _tiny_graph(n=n, f=f, edges=((0, 1), (1, 0), (1, 2), (2, 1),
                                      (3, 4), (4, 3), (0, 4), (4, 0)))
    cfg = C.ConvConfig(in_dim=f, out_dim=6, edge_dim=2, conv=conv)
    params = prm.materialize(C.conv_plan(cfg), jax.random.key(1))
    g, x, _ = G.graph_inputs(el)
    out = np.asarray(C.conv_apply(params, g, x, cfg))[:n]

    perm = np.array([2, 0, 4, 1, 3])
    inv = np.argsort(perm)
    nf2 = np.asarray(el["node_feat"]).copy()
    nf2[:n] = nf2[:n][perm]
    ei2 = np.asarray(el["edge_index"]).copy()
    val = ei2[:, 0] >= 0
    ei2[val] = inv[ei2[val]]
    el2 = dict(el, node_feat=jnp.asarray(nf2), edge_index=jnp.asarray(ei2))
    g2, x2, _ = G.graph_inputs(el2)
    out2 = np.asarray(C.conv_apply(params, g2, x2, cfg))[:n]
    np.testing.assert_allclose(out2, out[perm], rtol=2e-3, atol=2e-3)


def test_global_pooling_ignores_padding():
    x = jnp.asarray(RNG.standard_normal((6, 3)), jnp.float32)
    mask = jnp.array([True, True, True, False, False, False])
    for kind in ("add", "mean", "max"):
        got = global_pool(kind, x, mask)
        xs = np.asarray(x)[:3]
        want = {"add": xs.sum(0), "mean": xs.mean(0),
                "max": xs.max(0)}[kind]
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert global_pooling(("add", "mean", "max"), x, mask).shape == (9,)


@pytest.mark.parametrize("conv", ["gcn", "sage", "gin", "pna"])
def test_gnn_model_forward_and_grad(conv):
    cfg = config(conv, reduced=True)
    plan = G.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    batch = {k: jnp.asarray(v) for k, v in
             graph_batch(DATASETS["qm9"], 0, 4).items()}
    loss, grads = jax.value_and_grad(G.mse_loss)(params, cfg, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert gn > 0


def test_fixed_point_testbench_mae_shrinks_with_bits():
    """<32,16> quantization must beat <8,4> on MAE vs the float ref —
    the paper's fixed-vs-float testbench invariant."""
    cfg = config("gcn", reduced=True)
    plan = G.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    g = make_graph(DATASETS["qm9"], 0)
    el = {"node_feat": jnp.asarray(g.node_feat),
          "edge_index": jnp.asarray(g.edge_index),
          "edge_feat": jnp.asarray(g.edge_feat),
          "num_nodes": jnp.int32(g.num_nodes)}
    ref = G.apply(params, cfg, el, None)
    maes = {}
    for fpx in (Q.FPX(8, 4), Q.FPX(16, 8), Q.FPX(32, 16)):
        qp = Q.quantize_tree(params, fpx)
        out = G.apply(qp, cfg, el, fpx)
        maes[fpx.w] = float(jnp.mean(jnp.abs(out - ref)))
    assert maes[32] <= maes[16] <= maes[8]
    assert maes[32] < 1e-3


def test_gnn_training_reduces_loss():
    cfg = config("gcn", reduced=True)
    plan = G.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    ds = DATASETS["qm9"]

    @jax.jit
    def step(p, batch):
        loss, grads = jax.value_and_grad(G.mse_loss)(p, cfg, batch)
        p = jax.tree_util.tree_map(lambda w, g: w - 0.01 * g, p, grads)
        return p, loss

    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in graph_batch(ds, i, 8).items()}
        params, loss = step(params, batch)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
