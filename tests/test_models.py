"""Per-arch smoke tests: every assigned architecture instantiates a
REDUCED same-family config and runs one forward/train step + one decode
step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.models import lm
from repro.nn import param as prm
from repro.optim import adamw

RNG = np.random.default_rng(0)


def _batch(cfg, b=2, s=32):
    toks = jnp.asarray(
        RNG.integers(0, cfg.vocab_size,
                     (b, s if cfg.family != "audio" else s // 4)),
        jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["mem"] = jnp.ones((b, cfg.num_mem_tokens, cfg.mem_dim),
                                jnp.bfloat16)
    if cfg.family == "audio":
        batch["mem"] = jnp.ones((b, s, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    plan = lm.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    opt = prm.materialize(adamw.opt_plan(plan), jax.random.key(1))
    batch = _batch(cfg)

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, cfg, b))(p)
        p2, o2, m = adamw.apply_updates(adamw.OptConfig(), p, grads, o)
        return p2, o2, dict(m, loss=loss)

    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # a second step must change the loss (optimizer actually applied)
    _, _, m2 = step(p2, o2, batch)
    assert float(m2["loss"]) != float(m["loss"])


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    plan = lm.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    b, s = 2, 32
    mem_len = s if cfg.family == "audio" else cfg.num_mem_tokens
    cplan = lm.cache_plan(cfg, b, s, mem_len=mem_len)
    caches = jax.tree_util.tree_map(
        lambda sp: jnp.zeros(sp.shape, sp.dtype), prm.abstract(cplan))
    ids = jnp.zeros((b, 1), jnp.int32)
    logits, new_caches = jax.jit(
        lambda p, c, i, pos: lm.decode_step(p, cfg, c, i, pos)
    )(params, caches, ids, jnp.int32(0))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    jax.tree_util.tree_map(lambda a, b_: None, caches, new_caches)


@pytest.mark.parametrize("arch", [
    "qwen3-8b", "rwkv6-1.6b", "whisper-base",
    "jamba-1.5-large-398b",       # mamba single-step == chunked scan
    "deepseek-v2-236b",           # absorbed MLA decode == expanded prefill
    "llama4-scout-17b-a16e",      # MoE decode routing == prefill routing
])
def test_prefill_then_decode_consistent(arch):
    """Greedy token from prefill == decode-step replay of the prompt."""
    cfg = get_config(arch, reduced=True)
    plan = lm.model_plan(cfg)
    params = prm.materialize(plan, jax.random.key(0))
    b, s = 1, 8
    mem = None
    if cfg.family == "audio":
        mem = jnp.ones((b, s * 4, cfg.d_model), jnp.bfloat16)
    ids = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_pre, _ = lm.prefill(params, cfg, ids, mem)

    mem_len = s * 4 if cfg.family == "audio" else cfg.num_mem_tokens
    cplan = lm.cache_plan(cfg, b, s, mem_len=mem_len)
    caches = jax.tree_util.tree_map(
        lambda sp: jnp.zeros(sp.shape, sp.dtype), prm.abstract(cplan))
    if cfg.family == "audio":   # cross-attn caches come from the encoder
        _, pref_caches = lm.prefill(params, cfg, ids, mem)
        caches = jax.tree_util.tree_map(
            lambda full, part: part.astype(full.dtype)
            if full.shape == part.shape else full, caches, pref_caches)
    logits = None
    for t in range(s):
        logits, caches = lm.decode_step(params, cfg, caches, ids[:, t:t+1],
                                        jnp.int32(t))
    got = int(jnp.argmax(logits[0, -1]))
    want = int(jnp.argmax(logits_pre[0, -1]))
    assert got == want


def test_full_configs_param_counts():
    """Full configs build plans with the expected parameter scale."""
    expected = {"qwen3-8b": (7e9, 10e9),
                "internlm2-20b": (17e9, 23e9),
                "minitron-4b": (4e9, 6.5e9),
                "deepseek-coder-33b": (30e9, 38e9),
                "deepseek-v2-236b": (200e9, 260e9),
                "jamba-1.5-large-398b": (330e9, 430e9),
                "rwkv6-1.6b": (1.3e9, 2.2e9),
                "whisper-base": (50e6, 120e6)}
    for arch, (lo, hi) in expected.items():
        n = prm.count_params(lm.model_plan(get_config(arch)))
        assert lo <= n <= hi, f"{arch}: {n:,} params outside [{lo}, {hi}]"
