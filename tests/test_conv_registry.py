"""The conv registry contract: capability tuples, the DSE conv axis,
perf-model conv one-hots and the parity-grid axes all derive live from
``repro.core.convs.CONV_REGISTRY`` — the bugfix for the conv tuples
that used to be duplicated (and could drift) across convs.py, dse.py
and perf_model.py. A toy conv registered here must appear everywhere
with zero edits to any other module."""
import jax
import numpy as np

import parity
from repro.core import convs as Cv
from repro.core import dse
from repro.core import perf_model as PM
from repro.data import pipeline as P


def _dse_convs():
    return [n for n in Cv.CONV_TYPES if Cv.conv_spec(n).dse]


def test_capability_tuples_derive_from_registry():
    assert Cv.CONV_TYPES == tuple(Cv.CONV_REGISTRY)
    assert Cv.REORDERABLE_CONVS == tuple(
        n for n in Cv.CONV_TYPES if Cv.conv_spec(n).reorderable)
    assert Cv.RESIDENT_CONVS == tuple(
        n for n in Cv.CONV_TYPES if Cv.conv_spec(n).resident)
    # the attention conv is registered with the documented capabilities
    gat = Cv.conv_spec("gat")
    assert gat.attention and gat.partition_bitwise
    assert not gat.reorderable and not gat.resident
    assert gat.precisions == Cv.PRECISION_GRID
    with np.testing.assert_raises(ValueError):
        Cv.conv_spec("nope")


def test_dse_space_perf_features_and_registry_agree():
    """The agreement pin: dse.SPACE['conv'], the perf-model conv
    one-hots and the registry enumerate the same convs in the same
    order — the drift this PR's registry refactor closes."""
    dse_convs = _dse_convs()
    assert dse.SPACE["conv"] == dse_convs
    onehots = [f for f in PM.FEATURE_NAMES if f.startswith("conv_")]
    assert onehots == [f"conv_{c}" for c in dse_convs]
    assert parity.conv_axis() == tuple(Cv.CONV_TYPES)
    # featurization one-hot roundtrip, gat included
    rng = np.random.default_rng(0)
    d = dict(dse.sample_design(rng), conv="gat")
    v = PM.features(d)
    assert v[PM.FEATURE_NAMES.index("conv_gat")] == 1.0
    assert sum(v[PM.FEATURE_NAMES.index(f"conv_{c}")]
               for c in dse_convs) == 1.0
    # database rows recorded before the attention conv landed still
    # featurize — as non-attention designs (conv_gat stays cold)
    w = PM.features(dict(d, conv="gcn"))
    assert w[PM.FEATURE_NAMES.index("conv_gat")] == 0.0
    assert w[PM.FEATURE_NAMES.index("conv_gcn")] == 1.0


def test_toy_conv_appears_everywhere_without_edits():
    """register_conv('toy', ...) -> the conv shows up in dse.SPACE, the
    perf-model featurization, and the parity-grid parametrization, and
    its packed parity cell actually runs — no edits anywhere else."""
    assert "toy" not in Cv.CONV_TYPES
    n_features = len(PM.FEATURE_NAMES)
    try:
        Cv.register_conv("toy", Cv.gin_plan, Cv.gin_apply,
                         precisions=("fp32",))
        assert "toy" in Cv.CONV_TYPES
        # DSE search space
        assert "toy" in dse.SPACE["conv"]
        rng = np.random.default_rng(1)
        assert any(dse.sample_design(rng)["conv"] == "toy"
                   for _ in range(64))
        # perf-model featurization
        assert "conv_toy" in PM.FEATURE_NAMES
        assert len(PM.FEATURE_NAMES) == n_features + 1
        d = dict(dse.sample_design(rng), conv="toy")
        v = PM.features(d)
        assert len(v) == len(PM.FEATURE_NAMES)
        assert v[PM.FEATURE_NAMES.index("conv_toy")] == 1.0
        # parity-grid axes (what parametrizes the packed grid and what
        # the subprocess grids re-derive in the child)
        assert "toy" in parity.conv_axis()
        assert parity.precision_axis("toy") == ("fp32",)
        assert ("toy", "fp32") in parity.conv_precision_cases()
        assert "toy" not in parity.bitwise_convs()
        # and the cell itself runs: xla == pallas == padded oracle
        ds = P.GraphDataConfig(avg_nodes=8, max_nodes=64, max_edges=64,
                               node_feat_dim=5, edge_feat_dim=2, seed=3)
        parity.check_packed("toy", "fp32",
                            [P.make_graph(ds, i) for i in range(3)], ds)
    finally:
        Cv.unregister_conv("toy")
    assert "toy" not in Cv.CONV_TYPES
    assert "toy" not in dse.SPACE["conv"]
    assert "conv_toy" not in PM.FEATURE_NAMES
    assert len(PM.FEATURE_NAMES) == n_features
